//! CUTLASS-like hierarchical blocked GEMM engine.
//!
//! Mirrors the structure the paper builds on (CUTLASS 2.5): threadblock-level
//! tiles `(bm, bn, bk)`, warp-level tiles `(wm, wn, wk)` and `stages` of
//! software pipelining (the latter only affects the performance model — it
//! cannot change numerics). The engine owns the loop nest and panel
//! packing; a [`KernelBackend`] supplies the per-k-block numerics (plain
//! Tensor-Core, Markidis, Feng, or this paper's corrected variants).
//!
//! Numerically relevant structure faithfully modelled:
//! * output-element accumulation is chunked by the 8-wide instruction k
//!   (`mma.m16n8k8`) inside each backend;
//! * when `wk < bk`, a k-block is partitioned into `bk/wk` *k-slices* with
//!   independent accumulators that are reduced at tile epilogue in FP32 —
//!   this is why the paper observes "the order of addition is changed by the
//!   template parameters of CUTLASS, which slightly affects the error".

use super::matrix::Mat;

/// CUTLASS template parameters (Table 3's search space).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileConfig {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
    pub wm: usize,
    pub wn: usize,
    pub wk: usize,
    pub stages: usize,
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig { bm: 64, bn: 64, bk: 32, wm: 32, wn: 32, wk: 32, stages: 3 }
    }
}

impl TileConfig {
    /// Number of independent k-slices per k-block (split-k within a tile).
    pub fn k_slices(&self) -> usize {
        self.bk.div_ceil(self.wk)
    }

    /// Warps per threadblock (used by the performance model and the
    /// autotuner's occupancy filter).
    pub fn warps(&self) -> usize {
        self.bm.div_ceil(self.wm) * self.bn.div_ceil(self.wn) * self.k_slices()
    }

    /// Shared-memory footprint in bytes for FP16 operands (A and B panels,
    /// hi+lo copies, double-buffered across `stages`). Mirrors the paper's
    /// "required shared memory exceeds capacity" filter.
    pub fn smem_bytes_f16(&self) -> usize {
        // hi+lo halves of both panels: 2 bytes/elt × 2 (hi,lo)
        self.stages * (self.bm * self.bk + self.bk * self.bn) * 2 * 2
    }

    /// Shared-memory footprint for TF32 operands (4 bytes/elt, hi+lo).
    pub fn smem_bytes_tf32(&self) -> usize {
        self.stages * (self.bm * self.bk + self.bk * self.bn) * 4 * 2
    }
}

/// Per-output-tile accumulator state handed to backends.
///
/// `c` is the main FP32 accumulator; `dc` the correction-term accumulator
/// (kept in the Tensor Core, i.e. updated with RZ, in the paper's Code 3);
/// `dc2` the ΔA·ΔB accumulator used only by 4-term ablations.
pub struct TileState {
    pub c: Vec<f32>,
    pub dc: Vec<f32>,
    pub dc2: Vec<f32>,
}

impl TileState {
    pub fn new(mn: usize) -> TileState {
        TileState { c: vec![0.0; mn], dc: vec![0.0; mn], dc2: vec![0.0; mn] }
    }
}

/// Packed low-precision pieces of one operand panel — what every backend
/// actually multiplies. Piece meaning is backend-defined: `[value]` for
/// FP32 SIMT, `[quantized]` for plain Tensor-Core, `[hi, lo]` for the
/// split-correction methods, `[b0, b1, b2]` for the bf16 triple. Each
/// piece panel has the same packed row-major layout as the raw panel.
#[derive(Debug, Default, Clone)]
pub struct PackedPieces {
    pub n_pieces: usize,
    pub p: [Vec<f32>; 3],
}

impl PackedPieces {
    /// Decompose a packed raw panel elementwise into piece panels.
    pub fn split_from(&mut self, src: &[f32], n_pieces: usize, f: impl Fn(f32) -> [f32; 3]) {
        self.n_pieces = n_pieces;
        for p in self.p.iter_mut() {
            p.clear();
        }
        for &x in src {
            let e = f(x);
            for i in 0..n_pieces {
                self.p[i].push(e[i]);
            }
        }
    }
}

/// The numerics of one GEMM method, plugged into the tiled engine.
///
/// The split/quantize step is exposed separately from the multiply step so
/// an operand can be decomposed **once** and reused across many GEMMs (the
/// two-stage `Method::prepare` / `Method::run_prepared` API, the batched
/// engine, and the coordinator's `SplitCache` all build on this). Every
/// decomposition is a pure elementwise map, so splitting a whole operand
/// up front and packing piece panels yields bit-identical panels to
/// packing the raw panel and splitting it per k-block.
pub trait KernelBackend: Sync {
    fn name(&self) -> &'static str;

    /// How many piece panels this backend's decomposition produces (1–3).
    fn piece_count(&self) -> usize;

    /// Elementwise decomposition of one operand value into this backend's
    /// low-precision pieces; entries past [`piece_count`](Self::piece_count)
    /// are unused and must be 0.
    fn split_element(&self, x: f32) -> [f32; 3];

    /// Fold one k-block given pre-split packed piece panels (`a`: tm×kb,
    /// `b`: kb×tn per piece) into the tile state.
    fn process_kblock_pieces(
        &self,
        st: &mut TileState,
        a: &PackedPieces,
        b: &PackedPieces,
        tm: usize,
        tn: usize,
        kb: usize,
    );

    /// Fold one packed k-block (`a`: tm×kb, `b`: kb×tn, row-major f32
    /// *original* data) into the tile state: split the panels with
    /// [`split_element`](Self::split_element), then multiply the pieces.
    fn process_kblock(
        &self,
        st: &mut TileState,
        a: &[f32],
        b: &[f32],
        tm: usize,
        tn: usize,
        kb: usize,
    ) {
        let n = self.piece_count();
        let mut pa = PackedPieces::default();
        let mut pb = PackedPieces::default();
        pa.split_from(a, n, |x| self.split_element(x));
        pb.split_from(b, n, |x| self.split_element(x));
        self.process_kblock_pieces(st, &pa, &pb, tm, tn, kb);
    }

    /// Tile epilogue for one k-slice: produce the slice's FP32 output tile.
    fn finalize(&self, st: TileState, tm: usize, tn: usize) -> Vec<f32>;

    /// Tensor-Core MMA-term multiplier (how many low-precision GEMMs of the
    /// full problem size this method issues): 1 for plain TC, 4 for
    /// Markidis/Feng, 3 for the paper's eq. (24). 0 for SIMT. Feeds the
    /// performance model.
    fn tc_term_count(&self) -> usize;
}

/// Instruction-level k (mma.m16n8k8).
pub const INST_K: usize = 8;

/// Run the blocked GEMM `C = A·B` with the given backend and tile config.
pub fn gemm_tiled(a: &Mat, b: &Mat, cfg: &TileConfig, backend: &dyn KernelBackend) -> Mat {
    assert_eq!(a.cols, b.rows, "inner dimensions must agree");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let n_slices = cfg.k_slices();

    let mut a_panel: Vec<f32> = Vec::new();
    let mut b_panel: Vec<f32> = Vec::new();

    let mut i0 = 0;
    while i0 < m {
        let tm = cfg.bm.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let tn = cfg.bn.min(n - j0);
            let mut states: Vec<TileState> =
                (0..n_slices).map(|_| TileState::new(tm * tn)).collect();
            let mut k0 = 0;
            while k0 < k {
                let kb_total = cfg.bk.min(k - k0);
                // Partition the k-block across warp-k slices.
                let mut s = 0;
                let mut ks = 0;
                while ks < kb_total {
                    let kb = cfg.wk.min(kb_total - ks);
                    a.copy_sub_into(i0, k0 + ks, tm, kb, &mut a_panel);
                    b.copy_sub_into(k0 + ks, j0, kb, tn, &mut b_panel);
                    backend.process_kblock(&mut states[s], &a_panel, &b_panel, tm, tn, kb);
                    s += 1;
                    ks += kb;
                }
                k0 += kb_total;
            }
            // Epilogue: finalize each slice, reduce in FP32 (RN adds).
            let mut tile = vec![0.0f32; tm * tn];
            for st in states.drain(..) {
                let out = backend.finalize(st, tm, tn);
                for (t, o) in tile.iter_mut().zip(out.iter()) {
                    *t += *o;
                }
            }
            c.write_sub(i0, j0, tm, tn, &tile);
            j0 += tn;
        }
        i0 += tm;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::backends::SimtBackend;
    use crate::gemm::reference::gemm_f64;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut s = seed | 1;
        Mat::from_fn(rows, cols, |_, _| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
        })
    }

    #[test]
    fn tile_config_derived_quantities() {
        let cfg = TileConfig { bm: 128, bn: 64, bk: 64, wm: 64, wn: 32, wk: 32, stages: 3 };
        assert_eq!(cfg.k_slices(), 2);
        assert_eq!(cfg.warps(), 2 * 2 * 2);
        assert!(cfg.smem_bytes_f16() > 0);
        assert!(cfg.smem_bytes_tf32() == cfg.smem_bytes_f16() * 2);
    }

    #[test]
    fn ragged_sizes_covered() {
        // Sizes not divisible by any tile parameter must still be correct.
        let a = rand_mat(37, 53, 1);
        let b = rand_mat(53, 29, 2);
        let cfg = TileConfig { bm: 16, bn: 16, bk: 16, wm: 16, wn: 16, wk: 16, stages: 3 };
        let c = gemm_tiled(&a, &b, &cfg, &SimtBackend);
        let r = gemm_f64(&a, &b);
        let res = crate::gemm::error::relative_residual(&r, &c);
        assert!(res < 1e-6, "residual {res}");
    }

    #[test]
    fn k_slicing_changes_only_summation_order() {
        let a = rand_mat(24, 96, 3);
        let b = rand_mat(96, 24, 4);
        let one_slice = TileConfig { bk: 64, wk: 64, ..TileConfig::default() };
        let two_slices = TileConfig { bk: 64, wk: 32, ..TileConfig::default() };
        let c1 = gemm_tiled(&a, &b, &one_slice, &SimtBackend);
        let c2 = gemm_tiled(&a, &b, &two_slices, &SimtBackend);
        let r = gemm_f64(&a, &b);
        let e1 = crate::gemm::error::relative_residual(&r, &c1);
        let e2 = crate::gemm::error::relative_residual(&r, &c2);
        assert!(e1 < 1e-6 && e2 < 1e-6);
        // Different order => (almost certainly) different bits, same level.
        assert!((e1 / e2.max(1e-300)).log2().abs() < 6.0);
    }
}
