//! Table 3 — the CUTLASS template-parameter grid search: 3456 combinations,
//! filtered by the paper's three rules, then ranked for a target size.
//!
//! Paper: 3456 → 202 (halfhalf) / 200 (tf32tf32) survivors. Our filter
//! census reproduces the order of magnitude; the exact count differs
//! because the compile-feasibility rule is replaced by explicit
//! smem/occupancy limits (DESIGN.md §2).
//!
//! Run: `cargo bench --bench table3_autotune`

use tcec::autotune;
use tcec::bench_util::Table;
use tcec::experiments;
use tcec::gemm::{Method, OursBackend};
use tcec::perfmodel::A100;

fn main() {
    let probe = if tcec::bench_util::smoke() { 2 } else { 16 };
    println!("== Table 3: filter census (A100; accuracy probe {probe}x{probe}x{probe}) ==\n");
    experiments::table3(&A100, probe).print();

    println!("\n== top-10 configs for matmul-(1024,1024,1024), halfhalf ==\n");
    let be = OursBackend::halfhalf();
    let best = autotune::autotune(&A100, Method::OursHalfHalf, &be, 1024, probe, 10);
    let mut t = Table::new(&["bm", "bn", "bk", "wm", "wn", "wk", "stages", "score"]);
    for (c, s) in best {
        t.row(&[
            c.bm.to_string(),
            c.bn.to_string(),
            c.bk.to_string(),
            c.wm.to_string(),
            c.wn.to_string(),
            c.wk.to_string(),
            c.stages.to_string(),
            format!("{s:.2}"),
        ]);
    }
    t.print();
}
