//! Minimal CLI argument parser (clap is unavailable offline — DESIGN.md §2).
//! Syntax: `tcec <command> [positional...] [--flag value | --switch]`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--flag value` unless the next token is another flag / absent.
                let takes_value =
                    it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                let v = if takes_value { it.next().unwrap() } else { "true".to_string() };
                out.flags.insert(name.to_string(), v);
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> u64 {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> f64 {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("gemm 16 32");
        assert_eq!(a.command.as_deref(), Some("gemm"));
        assert_eq!(a.positional, vec!["16", "32"]);
    }

    #[test]
    fn flags_with_values_and_switches() {
        let a = parse("serve --workers 4 --verbose --method cutlass_halfhalf");
        assert_eq!(a.usize_flag("workers", 1), 4);
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.str_flag("method"), Some("cutlass_halfhalf"));
        assert_eq!(a.usize_flag("missing", 7), 7);
    }

    #[test]
    fn f64_flags_parse() {
        let a = parse("solve --cond 1e4 --tol 0.5");
        assert_eq!(a.f64_flag("cond", 1.0), 1e4);
        assert_eq!(a.f64_flag("tol", 1.0), 0.5);
        assert_eq!(a.f64_flag("missing", 2.5), 2.5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b 3");
        assert!(a.bool_flag("a"));
        assert_eq!(a.usize_flag("b", 0), 3);
    }
}
