//! §Perf telemetry overhead bench: what full observability (stage-span
//! tracing into the ring + the numerical-health counters, DESIGN.md §12)
//! costs on the served request path versus the same service with
//! telemetry off. The instrumented path adds a handful of `Instant`
//! reads, relaxed atomic bumps and one short ring-mutex hold per stage —
//! the target is under 5% per request at n = 256 (printed, not
//! asserted: CI boxes are noisy).
//!
//! Run: `cargo bench --bench telemetry_overhead` (`-- --smoke` for the
//! CI smoke lane).

use std::sync::Arc;
use tcec::bench_util::{bench, bench_params, smoke, Table};
use tcec::coordinator::{GemmService, Policy, SimExecutor};
use tcec::gemm::Method;
use tcec::telemetry::TelemetryConfig;

/// Requests per measured batch (amortizes clock overhead).
const REQS: usize = 16;

fn service(telemetry: TelemetryConfig) -> GemmService {
    // Fp32Simt forced: the cheapest backend, so the per-request telemetry
    // cost is the largest possible fraction of the measured time.
    GemmService::builder()
        .workers(2)
        .max_batch(8)
        .queue_cap(4096)
        .force_method(Method::Fp32Simt)
        .telemetry(telemetry)
        .build(Arc::new(SimExecutor::new()))
}

/// One measured round: REQS submits, then wait all.
fn round(svc: &GemmService, n: usize, seed: u64) {
    use tcec::matgen::urand;
    let tickets: Vec<_> = (0..REQS as u64)
        .map(|i| {
            svc.call(urand(n, n, -1.0, 1.0, seed + i), urand(n, n, -1.0, 1.0, seed + i + 500))
                .policy(Policy::StrictFp32)
                .submit()
                .expect("admitted")
        })
        .collect();
    for t in tickets {
        t.wait().expect("served");
    }
}

fn main() {
    let sizes: &[usize] = if smoke() { &[16] } else { &[64, 256] };
    let (wu, mi, mt) = bench_params(1, 3, 0.3);
    println!("== telemetry overhead: tracing+counters on vs off ==");
    println!("   ({REQS} requests per round, Fp32Simt forced, 2 workers; target < 5% at n=256)\n");
    let mut t = Table::new(&["n", "off us/req", "on us/req", "delta"]);
    for &n in sizes {
        let svc_off = service(TelemetryConfig::default());
        let s_off = bench(|| round(&svc_off, n, 1), wu, mi, mt);
        svc_off.shutdown();
        let svc_on = service(TelemetryConfig::full());
        let s_on = bench(|| round(&svc_on, n, 1), wu, mi, mt);
        svc_on.shutdown();
        let off = s_off.median_s / REQS as f64 * 1e6;
        let on = s_on.median_s / REQS as f64 * 1e6;
        t.row(&[
            n.to_string(),
            format!("{off:.1}"),
            format!("{on:.1}"),
            format!("{:+.1}%", (on / off - 1.0) * 100.0),
        ]);
    }
    t.print();
}
