//! Shard-engine scaling bench: wall-clock throughput of one large GEMM as
//! the work-stealing pool grows from 1 to N workers, plus steal/reduction
//! telemetry and the bit-identity check against the unsharded run.
//!
//! The simulated backends are CPU-bound, so the speedup ceiling is the
//! machine's core count (printed below) — the *shape* to look for is
//! monotonic throughput improvement 1 → N and a steal count that rises
//! with imbalance (ragged edge tiles).
//!
//! Run:  `cargo bench --bench shard_scaling`
//! JSON: `cargo bench --bench shard_scaling -- --json > BENCH_shard_scaling.json`

use std::sync::Arc;
use tcec::bench_util::{json_array, json_mode, JsonObj, Table};
use tcec::coordinator::{Executor, Policy, SimExecutor};
use tcec::gemm::Method;
use tcec::matgen::urand;
use tcec::shard::{plan, sharded_gemm, ShardConfig, WorkerPool};

fn main() {
    let smoke = tcec::bench_util::smoke();
    let json = json_mode();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if !json {
        println!("== shard_scaling: sharded GEMM throughput vs worker count ==");
        println!("   ({cores} host cores — speedup saturates there)\n");
    }

    // Ragged sizes: edge tiles create imbalance for the stealer to fix.
    let cases = if smoke {
        [(Method::Fp32Simt, 136, 136, 48), (Method::OursHalfHalf, 80, 80, 32)]
    } else {
        [(Method::Fp32Simt, 560, 560, 256), (Method::OursHalfHalf, 272, 272, 192)]
    };
    let worker_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };

    let mut case_rows: Vec<String> = Vec::new();
    for (method, m, n, k) in cases {
        let a = urand(m, k, -1.0, 1.0, 11);
        let b = urand(k, n, -1.0, 1.0, 12);
        if !json {
            println!("-- {} ({m} x {k}) * ({k} x {n}) --", method.name());
        }

        // Unsharded baseline under the plan's equivalent tile.
        let probe_cfg = ShardConfig { workers: 1, min_flops: 0, ..ShardConfig::default() };
        let p = plan(m, n, k, method, &probe_cfg).expect("plan");
        let t0 = std::time::Instant::now();
        let want = method.run(&a, &b, &p.equivalent_tile());
        let base_s = t0.elapsed().as_secs_f64();
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        if !json {
            println!("unsharded: {base_s:.3}s ({:.1} sim MFlop/s)", flops / base_s / 1e6);
        }

        let mut t = Table::new(&[
            "workers",
            "shards",
            "kslices",
            "time s",
            "MFlop/s",
            "speedup",
            "steals",
            "bit-identical",
        ]);
        let mut worker_rows: Vec<String> = Vec::new();
        let mut prev_time = f64::INFINITY;
        let mut monotone = true;
        for &w in worker_counts {
            let cfg = ShardConfig { workers: w, min_flops: 0, ..ShardConfig::default() };
            let p = plan(m, n, k, method, &cfg).expect("plan");
            let inner: Arc<dyn Executor> = Arc::new(SimExecutor::new());
            let pool = WorkerPool::new(w);
            // Warm one run, then measure the best of three.
            let _ = sharded_gemm(&a, &b, method, Policy::Fp32Accuracy, &p, &inner, &pool);
            let mut best = f64::INFINITY;
            let mut last = None;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                let (c, stats) =
                    sharded_gemm(&a, &b, method, Policy::Fp32Accuracy, &p, &inner, &pool);
                best = best.min(t0.elapsed().as_secs_f64());
                last = Some((c, stats));
            }
            let (c, stats) = last.unwrap();
            // Both cases keep kslices = 1 for every worker count (the M/N
            // grid alone covers the target), so one baseline serves all.
            let probe_tile = plan(m, n, k, method, &probe_cfg).unwrap().equivalent_tile();
            assert_eq!(p.equivalent_tile(), probe_tile);
            let identical = c.data == want.data;
            if w <= cores && best > prev_time * 1.05 {
                monotone = false;
            }
            if w <= cores {
                prev_time = best;
            }
            t.row(&[
                w.to_string(),
                p.shard_count().to_string(),
                p.kslices.to_string(),
                format!("{best:.3}"),
                format!("{:.1}", flops / best / 1e6),
                format!("{:.2}x", base_s / best),
                stats.steals.to_string(),
                if identical { "yes".into() } else { "NO — BUG".into() },
            ]);
            worker_rows.push(
                JsonObj::new()
                    .int("workers", w as u64)
                    .int("shards", p.shard_count() as u64)
                    .int("kslices", p.kslices as u64)
                    .num("time_s", best)
                    .num("mflops", flops / best / 1e6)
                    .num("speedup", base_s / best)
                    .int("steals", stats.steals)
                    .bool("bit_identical", identical)
                    .finish(),
            );
        }
        if !json {
            t.print();
            println!(
                "monotonic 1→min(N,cores): {}\n",
                if monotone { "yes" } else { "no (noisy host?)" }
            );
        }
        case_rows.push(
            JsonObj::new()
                .str("method", method.name())
                .int("m", m as u64)
                .int("n", n as u64)
                .int("k", k as u64)
                .num("unsharded_s", base_s)
                .bool("monotone", monotone)
                .raw("scaling", &json_array(&worker_rows))
                .finish(),
        );
    }
    if json {
        println!(
            "{}",
            JsonObj::new()
                .str("bench", "shard_scaling")
                .bool("smoke", smoke)
                .int("host_cores", cores as u64)
                .raw("cases", &json_array(&case_rows))
                .finish()
        );
    }
}
