"""L2 — the JAX compute graph that the Rust runtime executes.

The "model" for a GEMM-kernel paper is the GEMM itself plus the epilogue a
serving system wants fused: the entry points here are jitted functions of
``(a, b) -> (c,)`` that call the L1 Pallas kernel, lowered once by
``aot.py`` to HLO text and never run from Python at serve time.

``ec_gemm_chain`` exercises composition (two chained corrected GEMMs —
the shape of one transformer-MLP block) to prove the kernel fuses into a
larger graph; the e2e example serves the plain ``ec_gemm_model``.
"""

import jax.numpy as jnp

from .kernels import ec_gemm


def ec_gemm_model(a, b, variant="halfhalf"):
    """C = ec_gemm(A, B). Returned as a 1-tuple (AOT contract: the HLO's
    root is a tuple, unwrapped by the Rust side with ``to_tuple1``)."""
    return (ec_gemm.ec_gemm(a, b, variant=variant),)


def fp32_gemm_model(a, b):
    """Baseline FP32 GEMM artifact (same contract)."""
    return (ec_gemm.ec_gemm(a, b, variant="fp32"),)


def ec_gemm_chain(a, w1, w2, variant="halfhalf"):
    """Two corrected GEMMs with a gelu between — an MLP-block-shaped graph
    proving the kernel composes inside a bigger jit (L2 fusion test)."""
    h = ec_gemm.ec_gemm(a, w1, variant=variant)
    h = jnp.where(h > 0, h, 0.01 * h)  # cheap nonlinearity, f32-exact-ish
    return (ec_gemm.ec_gemm(h, w2, variant=variant),)
