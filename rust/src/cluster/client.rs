//! [`ClusterClient`], [`ClusterSession`], [`ClusterCall`] and
//! [`ClusterTicket`] — the cluster-facing mirror of `api::Client` /
//! `Session` / `GemmCall` / `Ticket` (DESIGN.md §15).
//!
//! The surface is deliberately isomorphic to the single-node API: the same
//! call builder knobs (policy, deadline, priority, tag), the same
//! consuming ticket state machine (`wait` / `wait_timeout` / `try_get` /
//! `cancel`), the same `GemmResult` and `ServiceError` taxonomy. What the
//! cluster adds lives entirely between submit and resolve:
//!
//! * **placement** — the routing key is the weight fingerprint of `B`
//!   ([`crate::planner::sampled_fingerprint`]); the ring maps it to a
//!   preference list of R distinct replicas, healthy members first (except
//!   on probe turns, which keep raw ring order so an unhealthy owner still
//!   sees traffic and can recover);
//! * **failover** — a submit-time `QueueFull` shed or a reply-time
//!   `ExecutorFailed` / `ShuttingDown` moves the attempt to the next
//!   replica, re-submitting from the retained operands with the remaining
//!   deadline budget. Because every node computes bit-identically, the
//!   moved request returns the same bytes the dead node would have;
//! * **hedging** — under [`HedgePolicy::After`] / [`HedgePolicy::P99`] a
//!   duplicate attempt launches on the next replica once the primary has
//!   been outstanding past its budget; the first resolution wins and the
//!   loser is cancelled;
//! * **exactly-once accounting** — however many attempts run, the logical
//!   request increments `requests` once at admission and exactly one of
//!   `completed` / `failed` / `expired` / `cancelled` at resolution (an
//!   abandoned pending ticket resolves as cancelled via `Drop`), so the
//!   ledger identity holds at cluster scope with hedges excluded by
//!   construction — a hedge win counts the *request* completed once and
//!   bumps only `hedge_wins` on top.

use super::metrics::{ClusterMetrics, ClusterSnapshot, NodeSnapshot};
use super::node::Node;
use super::quota::TenantQuotas;
use super::ring::HashRing;
use super::{ClusterConfig, HedgePolicy};
use crate::api::client::CallOptions;
use crate::api::{CancelToken, GemmResult, Priority, ServiceError, Ticket};
use crate::coordinator::{GemmOutcome, Policy};
use crate::gemm::Mat;
use crate::planner::sampled_fingerprint;
use crate::telemetry::Stage;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Poll granularity of the hedged wait loop. Hedge budgets are stage-p99
/// sums (tens of microseconds at the smallest), so 100 µs resolution is
/// fine-grained enough while keeping the loop cheap.
const SPIN: Duration = Duration::from_micros(100);

/// Shared state behind every cluster handle.
pub(crate) struct ClusterInner {
    nodes: Vec<Node>,
    ring: HashRing,
    cfg: ClusterConfig,
    metrics: Arc<ClusterMetrics>,
    quotas: Option<TenantQuotas>,
    probe_ctr: AtomicU64,
}

impl ClusterInner {
    /// The routing key of a call: the (sampled) content fingerprint of the
    /// weight operand `B` — the same bytes-in-same-key function on every
    /// handle and across rebuilds, which is what makes placement
    /// deterministic and cache-affine.
    fn route_key(&self, b: &Mat) -> u128 {
        sampled_fingerprint(&b.data, self.cfg.route_probe)
    }

    /// Replica set of one key in static ring order (health-blind).
    fn replica_set(&self, b: &Mat) -> Vec<usize> {
        self.ring
            .route(self.route_key(b), self.cfg.replication.max(1))
            .into_iter()
            .map(|m| m as usize)
            .collect()
    }

    /// Preference list for one submission: the replica set, stably
    /// reordered healthy-first — except every `probe_every`-th submission,
    /// which keeps raw ring order so a deprioritized owner still sees a
    /// request and can flip back to healthy on success.
    fn prefs_for(&self, b: &Mat) -> Vec<usize> {
        let prefs = self.replica_set(b);
        let probe_turn = self.cfg.probe_every > 0
            && self.probe_ctr.fetch_add(1, Ordering::Relaxed) % self.cfg.probe_every as u64 == 0;
        if probe_turn {
            return prefs;
        }
        let is_healthy = |i: usize| self.nodes.get(i).is_some_and(Node::is_healthy);
        let mut ordered: Vec<usize> = prefs.iter().copied().filter(|&i| is_healthy(i)).collect();
        ordered.extend(prefs.iter().copied().filter(|&i| !is_healthy(i)));
        ordered
    }

    fn node(&self, nid: usize) -> Option<&Node> {
        self.nodes.get(nid)
    }
}

/// Shared-ownership handle to a running cluster. Mirrors `api::Client`.
#[derive(Clone)]
pub struct ClusterClient {
    inner: Arc<ClusterInner>,
}

impl ClusterClient {
    pub(crate) fn from_parts(nodes: Vec<Node>, cfg: ClusterConfig) -> ClusterClient {
        let ring = HashRing::new(nodes.len(), cfg.vnodes);
        let quotas = cfg.quota.map(TenantQuotas::new);
        ClusterClient {
            inner: Arc::new(ClusterInner {
                nodes,
                ring,
                cfg,
                metrics: Arc::new(ClusterMetrics::new()),
                quotas,
                probe_ctr: AtomicU64::new(1),
            }),
        }
    }

    /// Start building one GEMM call (`C = A·B`) against the cluster.
    pub fn call(&self, a: Mat, b: Mat) -> ClusterCall {
        ClusterCall { inner: Arc::clone(&self.inner), a, b, opts: CallOptions::default() }
    }

    /// A new session over this cluster with no defaults set.
    pub fn session(&self) -> ClusterSession {
        ClusterSession { inner: Arc::clone(&self.inner), defaults: CallOptions::default() }
    }

    /// The member nodes, in ring-id order.
    pub fn nodes(&self) -> &[Node] {
        &self.inner.nodes
    }

    /// The cluster-scope ledger.
    pub fn metrics(&self) -> Arc<ClusterMetrics> {
        Arc::clone(&self.inner.metrics)
    }

    /// The static placement of a weight matrix: the replica set (node
    /// indices, preference order) the ring assigns its fingerprint,
    /// ignoring health and probing. Deterministic across handles and
    /// rebuilds — the property the router determinism tests pin.
    pub fn route_of(&self, b: &Mat) -> Vec<usize> {
        self.inner.replica_set(b)
    }

    /// Cluster counters plus one full snapshot per node (the source of
    /// truth behind the `node`-labeled exposition).
    pub fn snapshot(&self) -> ClusterSnapshot {
        let nodes = self
            .inner
            .nodes
            .iter()
            .map(|n| {
                let execute_p99 = n
                    .service()
                    .tracer()
                    .map(|t| {
                        let ns: u64 = t
                            .stage_stats()
                            .iter()
                            .filter(|s| s.stage == Stage::Execute)
                            .map(|s| s.p99_ns)
                            .sum();
                        Duration::from_nanos(ns)
                    })
                    .unwrap_or_default();
                NodeSnapshot {
                    name: n.name().to_string(),
                    healthy: n.is_healthy(),
                    execute_p99,
                    service: n.service().metrics().snapshot(),
                }
            })
            .collect();
        ClusterSnapshot { counters: self.inner.metrics.snapshot_counters(), nodes }
    }

    /// Stop admission on every node (in-flight work drains).
    pub fn close(&self) {
        for n in &self.inner.nodes {
            n.service().close();
        }
    }

    /// Close every node, then release this handle. Each node service joins
    /// its threads when its last owner drops (`GemmService: Drop`), so a
    /// sole-owner shutdown is a full join.
    pub fn shutdown(self) {
        self.close();
    }
}

/// A bundle of per-call defaults over one cluster. Mirrors `api::Session`.
#[derive(Clone)]
pub struct ClusterSession {
    inner: Arc<ClusterInner>,
    defaults: CallOptions,
}

impl ClusterSession {
    /// Default accuracy policy for calls of this session.
    pub fn policy(mut self, policy: Policy) -> ClusterSession {
        self.defaults.policy = Some(policy);
        self
    }

    /// Default relative deadline for calls of this session.
    pub fn deadline(mut self, deadline: Duration) -> ClusterSession {
        self.defaults.deadline = Some(deadline);
        self
    }

    /// Default intake lane for calls of this session.
    pub fn priority(mut self, priority: Priority) -> ClusterSession {
        self.defaults.priority = priority;
        self
    }

    /// Default tag — also the tenant key of the quota ledger.
    pub fn tag(mut self, tag: impl Into<Arc<str>>) -> ClusterSession {
        self.defaults.tag = Some(tag.into());
        self
    }

    /// Start building a call seeded with this session's defaults.
    pub fn call(&self, a: Mat, b: Mat) -> ClusterCall {
        ClusterCall { inner: Arc::clone(&self.inner), a, b, opts: self.defaults.clone() }
    }
}

/// Builder for one clustered GEMM call. Terminal operations:
/// [`ClusterCall::submit`] or [`ClusterCall::wait`].
#[must_use = "a ClusterCall does nothing until submit() or wait()"]
pub struct ClusterCall {
    inner: Arc<ClusterInner>,
    a: Mat,
    b: Mat,
    opts: CallOptions,
}

impl ClusterCall {
    /// Accuracy policy for this call (default: `Policy::Fp32Accuracy`).
    pub fn policy(mut self, policy: Policy) -> Self {
        self.opts.policy = Some(policy);
        self
    }

    /// Relative deadline, enforced end-to-end: failover re-submissions and
    /// hedges receive only the remaining budget.
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.opts.deadline = Some(deadline);
        self
    }

    /// Intake lane on whichever node serves the call.
    pub fn priority(mut self, priority: Priority) -> Self {
        self.opts.priority = priority;
        self
    }

    /// Free-form label echoed back in `GemmOutcome::tag`; doubles as the
    /// tenant key when per-tenant quotas are configured.
    pub fn tag(mut self, tag: impl Into<Arc<str>>) -> Self {
        self.opts.tag = Some(tag.into());
        self
    }

    /// Admit the call: spend a quota token, route by weight fingerprint,
    /// and submit to the first replica that accepts (submit-time sheds
    /// fail over to the next replica synchronously). Returns the last
    /// replica's error when every replica refused; `InvalidShape` is
    /// terminal immediately (no node would accept it).
    pub fn submit(self) -> Result<ClusterTicket, ServiceError> {
        let ClusterCall { inner, a, b, opts } = self;
        if let Some(q) = &inner.quotas {
            if !q.try_acquire(opts.tag.as_deref(), Instant::now()) {
                inner.metrics.on_quota_rejected();
                inner.metrics.on_rejected();
                return Err(ServiceError::QueueFull { queue_cap: q.burst() as usize });
            }
        }
        let mut pending = inner.prefs_for(&b);
        let retain = pending.len() > 1 || !matches!(inner.cfg.hedge, HedgePolicy::Off);
        let submitted = Instant::now();
        let mut admitted: Option<(usize, Ticket)> = None;
        let mut last_err = ServiceError::ShuttingDown;
        while !pending.is_empty() {
            let nid = pending.remove(0);
            let Some(node) = inner.node(nid) else { continue };
            match node.service().submit_call(a.clone(), b.clone(), opts.clone()) {
                Ok(t) => {
                    admitted = Some((nid, t));
                    break;
                }
                Err(e) => {
                    if matches!(&e, ServiceError::InvalidShape { .. }) {
                        return Err(e);
                    }
                    if matches!(&e, ServiceError::QueueFull { .. }) {
                        inner.metrics.on_shed();
                        node.note_shed(inner.cfg.shed_unhealthy_after);
                    } else if matches!(&e, ServiceError::ShuttingDown) {
                        node.mark_failed();
                    }
                    last_err = e;
                }
            }
        }
        let Some((nid, ticket)) = admitted else {
            inner.metrics.on_rejected();
            return Err(last_err);
        };
        inner.metrics.on_request();
        let id = inner.metrics.next_id();
        let deadline = opts.deadline;
        let cancel = CancelToken::new();
        Ok(ClusterTicket {
            inner,
            id,
            submitted,
            deadline,
            opts,
            retained: retain.then(|| (a, b)),
            prefs: pending,
            primary: Some((nid, ticket)),
            hedge: None,
            cancel,
            finalized: false,
        })
    }

    /// Admit and block for the reply: `submit()` + `ClusterTicket::wait()`.
    pub fn wait(self) -> GemmResult {
        self.submit().and_then(|t| t.wait())
    }
}

/// Handle to one admitted clustered GEMM call — the *logical* request.
/// Child `api::Ticket`s (the primary attempt, failover re-submissions, at
/// most one live hedge) are owned and driven internally; the caller sees
/// one consuming state machine identical to the single-node `Ticket`.
#[must_use = "a ClusterTicket holds the only handle to the call's result"]
pub struct ClusterTicket {
    inner: Arc<ClusterInner>,
    id: u64,
    submitted: Instant,
    deadline: Option<Duration>,
    opts: CallOptions,
    retained: Option<(Mat, Mat)>,
    /// Replicas not yet attempted, in preference order.
    prefs: Vec<usize>,
    primary: Option<(usize, Ticket)>,
    hedge: Option<(usize, Ticket)>,
    cancel: CancelToken,
    finalized: bool,
}

impl ClusterTicket {
    /// The cluster-assigned logical request id (matches the resolved
    /// `GemmOutcome::id`, whichever node computed it).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// When the call was admitted by the cluster.
    pub fn submitted_at(&self) -> Instant {
        self.submitted
    }

    /// Request cancellation of the logical request and every live attempt.
    /// Best-effort with the same race semantics as `api::Ticket::cancel`.
    pub fn cancel(&self) {
        self.cancel.cancel();
        self.cancel_children();
    }

    /// A cancellation handle that outlives this ticket.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Block until the logical request resolves. Failover and hedging run
    /// inside this call; `ExecutorFailed` is returned only when every
    /// replica in the preference list failed.
    pub fn wait(mut self) -> GemmResult {
        loop {
            // Fast path: with hedging off at most one attempt is ever
            // outstanding — block on it instead of polling.
            if matches!(self.inner.cfg.hedge, HedgePolicy::Off) {
                if let Some((nid, t)) = self.primary.take() {
                    let res = t.wait();
                    if let Some(r) = self.settle(nid, res, false) {
                        return r;
                    }
                    continue;
                }
            }
            if let Some(r) = self.poll_once() {
                return r;
            }
            thread::sleep(SPIN);
        }
    }

    /// Like [`ClusterTicket::wait`] with a local patience bound:
    /// `Ok(result)` when resolved within `timeout`, `Err(self)` otherwise.
    pub fn wait_timeout(mut self, timeout: Duration) -> Result<GemmResult, ClusterTicket> {
        let start = Instant::now();
        loop {
            if let Some(r) = self.poll_once() {
                return Ok(r);
            }
            if start.elapsed() >= timeout {
                return Err(self);
            }
            thread::sleep(SPIN);
        }
    }

    /// Non-blocking poll: `Ok(result)` when already resolved (driving one
    /// step of failover/hedging if due), `Err(self)` while pending.
    pub fn try_get(mut self) -> Result<GemmResult, ClusterTicket> {
        match self.poll_once() {
            Some(r) => Ok(r),
            None => Err(self),
        }
    }

    /// One scheduling step: check cancellation, poll both attempts, settle
    /// whatever resolved, and launch a hedge if its budget elapsed.
    /// Returns the terminal result once the logical request resolves.
    fn poll_once(&mut self) -> Option<GemmResult> {
        if self.cancel.is_cancelled() {
            self.cancel_children();
            return Some(self.finalize_err(ServiceError::Cancelled));
        }
        if let Some((nid, t)) = self.primary.take() {
            match t.try_get() {
                Ok(res) => {
                    if let Some(r) = self.settle(nid, res, false) {
                        return Some(r);
                    }
                }
                Err(t) => self.primary = Some((nid, t)),
            }
        }
        if let Some((nid, t)) = self.hedge.take() {
            match t.try_get() {
                Ok(res) => {
                    if let Some(r) = self.settle(nid, res, true) {
                        return Some(r);
                    }
                }
                Err(t) => self.hedge = Some((nid, t)),
            }
        }
        if self.primary.is_none() && self.hedge.is_none() {
            // Unreachable by construction (settle refills or finalizes),
            // kept as a terminal backstop so the loop can never spin on a
            // ticket with no live attempt.
            return Some(self.finalize_exhausted(ServiceError::ShuttingDown));
        }
        self.maybe_hedge();
        None
    }

    /// Resolve one attempt's reply. `None` means the logical request is
    /// still in flight (the other attempt lives, or a failover
    /// re-submission was admitted); `Some` is the terminal result.
    fn settle(&mut self, nid: usize, res: GemmResult, was_hedge: bool) -> Option<GemmResult> {
        match res {
            Ok(out) => {
                if let Some(n) = self.inner.node(nid) {
                    n.mark_ok();
                }
                Some(self.finalize_ok(out, was_hedge))
            }
            Err(e) => {
                let other_live =
                    if was_hedge { self.primary.is_some() } else { self.hedge.is_some() };
                if matches!(&e, ServiceError::ExecutorFailed { .. } | ServiceError::ShuttingDown)
                {
                    if let Some(n) = self.inner.node(nid) {
                        n.mark_failed();
                    }
                    if other_live || self.resubmit() {
                        return None;
                    }
                    return Some(self.finalize_exhausted(e));
                }
                if matches!(&e, ServiceError::QueueFull { .. }) {
                    if let Some(n) = self.inner.node(nid) {
                        n.note_shed(self.inner.cfg.shed_unhealthy_after);
                    }
                    self.inner.metrics.on_shed();
                    if other_live || self.resubmit() {
                        return None;
                    }
                    return Some(self.finalize_exhausted(e));
                }
                if matches!(&e, ServiceError::DeadlineExceeded { .. }) && other_live {
                    // This attempt ran out of budget but the other might
                    // still make it; drop only this one.
                    return None;
                }
                Some(self.finalize_err(e))
            }
        }
    }

    /// Fail the current attempt over to the next untried replica. Returns
    /// `false` when no operands were retained, no replica remains, or the
    /// deadline budget is exhausted.
    fn resubmit(&mut self) -> bool {
        let Some((ra, rb)) = self.retained.clone() else { return false };
        while !self.prefs.is_empty() {
            let nid = self.prefs.remove(0);
            let Some(node) = self.inner.node(nid) else { continue };
            let Some(opts) = self.remaining_opts() else { return false };
            match node.service().submit_call(ra.clone(), rb.clone(), opts) {
                Ok(t) => {
                    self.primary = Some((nid, t));
                    self.inner.metrics.on_failover();
                    return true;
                }
                Err(e) => {
                    if matches!(&e, ServiceError::QueueFull { .. }) {
                        self.inner.metrics.on_shed();
                        node.note_shed(self.inner.cfg.shed_unhealthy_after);
                    } else if matches!(&e, ServiceError::ShuttingDown) {
                        node.mark_failed();
                    }
                }
            }
        }
        false
    }

    /// Launch the hedge attempt once the policy's budget has elapsed and a
    /// replica remains to hedge onto.
    fn maybe_hedge(&mut self) {
        if self.hedge.is_some() || self.primary.is_none() || self.prefs.is_empty() {
            return;
        }
        let budget = match self.inner.cfg.hedge {
            HedgePolicy::Off => return,
            HedgePolicy::After(d) => d,
            HedgePolicy::P99 { floor } => self
                .primary
                .as_ref()
                .and_then(|(nid, _)| self.inner.node(*nid))
                .map(|n| n.p99_budget(floor))
                .unwrap_or(floor),
        };
        if self.submitted.elapsed() < budget {
            return;
        }
        let Some((ra, rb)) = self.retained.clone() else { return };
        while !self.prefs.is_empty() {
            let nid = self.prefs.remove(0);
            let Some(node) = self.inner.node(nid) else { continue };
            let Some(opts) = self.remaining_opts() else { return };
            match node.service().submit_call(ra.clone(), rb.clone(), opts) {
                Ok(t) => {
                    self.hedge = Some((nid, t));
                    self.inner.metrics.on_hedge();
                    return;
                }
                Err(e) => {
                    if matches!(&e, ServiceError::QueueFull { .. }) {
                        self.inner.metrics.on_shed();
                        node.note_shed(self.inner.cfg.shed_unhealthy_after);
                    } else if matches!(&e, ServiceError::ShuttingDown) {
                        node.mark_failed();
                    }
                }
            }
        }
    }

    /// The call options for a follow-up attempt: the original knobs with
    /// the deadline rebased to the remaining end-to-end budget. `None`
    /// when the budget is already spent.
    fn remaining_opts(&self) -> Option<CallOptions> {
        let mut opts = self.opts.clone();
        if let Some(d) = self.deadline {
            let rem = d.checked_sub(self.submitted.elapsed())?;
            if rem.is_zero() {
                return None;
            }
            opts.deadline = Some(rem);
        }
        Some(opts)
    }

    fn cancel_children(&self) {
        if let Some((_, t)) = &self.primary {
            t.cancel();
        }
        if let Some((_, t)) = &self.hedge {
            t.cancel();
        }
    }

    /// Terminal success: count `completed` exactly once, rebrand the
    /// outcome with the cluster-logical id, cancel the losing attempt.
    fn finalize_ok(&mut self, mut out: GemmOutcome, was_hedge: bool) -> GemmResult {
        self.finalized = true;
        self.cancel_children();
        out.id = self.id;
        self.inner.metrics.on_completed();
        if was_hedge {
            self.inner.metrics.on_hedge_win();
        }
        Ok(out)
    }

    /// Terminal failure: count exactly one of expired / cancelled /
    /// failed, by the error's variant.
    fn finalize_err(&mut self, e: ServiceError) -> GemmResult {
        self.finalized = true;
        self.cancel_children();
        if matches!(&e, ServiceError::DeadlineExceeded { .. }) {
            self.inner.metrics.on_expired();
        } else if matches!(&e, ServiceError::Cancelled) {
            self.inner.metrics.on_cancelled();
        } else {
            self.inner.metrics.on_failed();
        }
        Err(e)
    }

    /// Terminal failure after failover came up empty: when the end-to-end
    /// deadline is the real reason no replica could take the retry, report
    /// (and count) expiry rather than the last node's error.
    fn finalize_exhausted(&mut self, e: ServiceError) -> GemmResult {
        let waited = self.submitted.elapsed();
        if self.deadline.is_some_and(|d| waited >= d) {
            return self.finalize_err(ServiceError::DeadlineExceeded { waited });
        }
        self.finalize_err(e)
    }
}

impl Drop for ClusterTicket {
    /// Abandoning a pending logical request resolves it as cancelled —
    /// the one remaining path to a terminal counter, which is what keeps
    /// the cluster ledger identity unconditional.
    fn drop(&mut self) {
        if self.finalized {
            return;
        }
        self.finalized = true;
        self.cancel_children();
        self.inner.metrics.on_cancelled();
    }
}
