//! CUTLASS template-parameter autotuning (paper §"Parameter tuning of
//! CUTLASS", Table 3).
//!
//! The paper grid-searches `bm, bn, bk ∈ {16,32,64,128}`, `wm, wn, wk ∈
//! {16,32,64}` and `stages ∈ {3,4}` (3456 combinations) and filters with
//! three rules: warp tiles must fit in the threadblock tile, the shared
//! memory footprint must fit, and the measured residual must stay below
//! 0.1. We reproduce the space, the filters (shared-memory limits from the
//! target GPU, the residual check run on the bit-exact simulator) and the
//! ranking, scoring surviving configs with the throughput projection plus a
//! tile-quantization penalty for the given problem size.

use crate::gemm::{gemm_f64, gemm_tiled, relative_residual, KernelBackend, TileConfig};
use crate::matgen::urand;
use crate::perfmodel::{projected_tflops, GpuSpec};

/// Residual threshold of the paper's third filter rule.
pub const ERROR_THRESHOLD: f64 = 0.1;

/// Table 3's search space: 4³ × 3³ × 2 = 3456 combinations.
pub fn search_space() -> Vec<TileConfig> {
    let block = [16usize, 32, 64, 128];
    let warp = [16usize, 32, 64];
    let stages = [3usize, 4];
    let mut out = Vec::with_capacity(3456);
    for &bm in &block {
        for &bn in &block {
            for &bk in &block {
                for &wm in &warp {
                    for &wn in &warp {
                        for &wk in &warp {
                            for &st in &stages {
                                out.push(TileConfig { bm, bn, bk, wm, wn, wk, stages: st });
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Why a config was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reject {
    /// A warp tile dimension exceeds its threadblock tile dimension.
    WarpExceedsBlock,
    /// Shared-memory footprint exceeds the GPU's per-block capacity.
    SmemOverflow,
    /// Too many warps per threadblock (>1024 threads).
    TooManyWarps,
    /// Residual above [`ERROR_THRESHOLD`] on the probe GEMM.
    ErrorTooLarge,
}

/// Structural filters (rules 1–2 + occupancy); cheap, no simulation.
pub fn structural_filter(cfg: &TileConfig, gpu: &GpuSpec, tf32: bool) -> Result<(), Reject> {
    if cfg.wm > cfg.bm || cfg.wn > cfg.bn || cfg.wk > cfg.bk {
        return Err(Reject::WarpExceedsBlock);
    }
    if cfg.warps() > 32 {
        return Err(Reject::TooManyWarps);
    }
    let smem = if tf32 { cfg.smem_bytes_tf32() } else { cfg.smem_bytes_f16() };
    if smem > gpu.smem_limit_bytes {
        return Err(Reject::SmemOverflow);
    }
    Ok(())
}

/// Accuracy filter (rule 3): run the probe GEMM on the simulator.
pub fn accuracy_filter(
    cfg: &TileConfig,
    backend: &dyn KernelBackend,
    probe: usize,
) -> Result<f64, Reject> {
    let a = urand(probe, probe, -1.0, 1.0, 0x7ab1e3);
    let b = urand(probe, probe, -1.0, 1.0, 0x7ab1e4);
    let c = gemm_tiled(&a, &b, cfg, backend);
    let r = gemm_f64(&a, &b);
    let e = relative_residual(&r, &c);
    if e > ERROR_THRESHOLD {
        Err(Reject::ErrorTooLarge)
    } else {
        Ok(e)
    }
}

/// Filtering outcome statistics (the paper reports 3456 → 202 / 200).
#[derive(Debug, Default, Clone)]
pub struct FilterStats {
    pub total: usize,
    pub warp_exceeds_block: usize,
    pub smem_overflow: usize,
    pub too_many_warps: usize,
    pub error_too_large: usize,
    pub survivors: usize,
}

/// Run the full filter pipeline. The accuracy probe runs only on configs
/// that pass the structural rules (matching the paper's pipeline, where
/// only compilable kernels are error-checked). `probe = 0` skips the
/// accuracy rule (structural-only census).
pub fn filter_space(
    gpu: &GpuSpec,
    tf32: bool,
    backend: Option<&dyn KernelBackend>,
    probe: usize,
) -> (Vec<TileConfig>, FilterStats) {
    let mut stats = FilterStats::default();
    let mut ok = Vec::new();
    for cfg in search_space() {
        stats.total += 1;
        match structural_filter(&cfg, gpu, tf32) {
            Err(Reject::WarpExceedsBlock) => stats.warp_exceeds_block += 1,
            Err(Reject::SmemOverflow) => stats.smem_overflow += 1,
            Err(Reject::TooManyWarps) => stats.too_many_warps += 1,
            Err(Reject::ErrorTooLarge) => unreachable!(),
            Ok(()) => {
                if let Some(be) = backend {
                    match accuracy_filter(&cfg, be, probe) {
                        Ok(_) => {
                            stats.survivors += 1;
                            ok.push(cfg);
                        }
                        Err(_) => stats.error_too_large += 1,
                    }
                } else {
                    stats.survivors += 1;
                    ok.push(cfg);
                }
            }
        }
    }
    (ok, stats)
}

/// Tile-quantization efficiency: fraction of launched CTA work that is
/// useful for an n×n problem (full tiles vs padded edges).
pub fn quantization_efficiency(cfg: &TileConfig, n: usize) -> f64 {
    let tiles_m = n.div_ceil(cfg.bm);
    let tiles_n = n.div_ceil(cfg.bn);
    let launched = (tiles_m * cfg.bm) as f64 * (tiles_n * cfg.bn) as f64;
    (n * n) as f64 / launched
}

/// Score a surviving config for problem size `n` on `gpu`: projected
/// saturation throughput × tile-quantization efficiency × a data-reuse
/// factor × a mild pipeline bonus for more stages on large k.
pub fn score(cfg: &TileConfig, gpu: &GpuSpec, method: crate::gemm::Method, n: usize) -> f64 {
    let base = projected_tflops(gpu, method, n);
    let stage_bonus = if n >= 1024 && cfg.stages == 4 { 1.03 } else { 1.0 };
    // Larger bk amortizes the epilogue; tiny bk pays per-block overhead.
    let bk_eff = (cfg.bk as f64 / (cfg.bk as f64 + 16.0)).sqrt();
    // Data reuse: a CTA tile's flop/byte ratio is the harmonic mean of
    // (bm, bn) — small tiles re-stream their panels and go memory-bound.
    // Saturates once reuse clears the machine balance (~32 flop/B).
    let hm = 2.0 / (1.0 / cfg.bm as f64 + 1.0 / cfg.bn as f64);
    let reuse_eff = hm / (hm + 32.0);
    base * quantization_efficiency(cfg, n) * stage_bonus * bk_eff * reuse_eff
}

/// Full autotune: filter, score, return the top `top` configs (descending).
pub fn autotune(
    gpu: &GpuSpec,
    method: crate::gemm::Method,
    backend: &dyn KernelBackend,
    n: usize,
    probe: usize,
    top: usize,
) -> Vec<(TileConfig, f64)> {
    let tf32 = matches!(method, crate::gemm::Method::OursTf32 | crate::gemm::Method::Tf32Tc);
    let (ok, _) = filter_space(gpu, tf32, Some(backend), probe);
    let mut scored: Vec<(TileConfig, f64)> =
        ok.into_iter().map(|c| (c, score(&c, gpu, method, n))).collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    scored.truncate(top);
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{Method, OursBackend};
    use crate::perfmodel::A100;

    #[test]
    fn space_size_matches_table3() {
        assert_eq!(search_space().len(), 3456);
    }

    #[test]
    fn structural_filter_rules() {
        let gpu = &A100;
        // Warp > block rejected.
        let bad = TileConfig { bm: 16, bn: 16, bk: 16, wm: 32, wn: 16, wk: 16, stages: 3 };
        assert_eq!(structural_filter(&bad, gpu, false), Err(Reject::WarpExceedsBlock));
        // Huge smem rejected for tf32 (4-byte elements) but the capacity
        // rule must keep *some* large configs for f16.
        let big = TileConfig { bm: 128, bn: 128, bk: 128, wm: 64, wn: 64, wk: 64, stages: 4 };
        assert_eq!(structural_filter(&big, gpu, true), Err(Reject::SmemOverflow));
        // A normal config passes.
        let ok = TileConfig::default();
        assert_eq!(structural_filter(&ok, gpu, false), Ok(()));
    }

    #[test]
    fn census_reduces_space_like_paper() {
        // The paper filters 3456 → ~200. Our structural census (without the
        // accuracy probe) must land in the same order of magnitude.
        let (ok, stats) = filter_space(&A100, false, None, 0);
        assert_eq!(stats.total, 3456);
        assert_eq!(ok.len(), stats.survivors);
        assert!(
            (100..=1200).contains(&ok.len()),
            "{} survivors (paper: 202)",
            ok.len()
        );
    }

    #[test]
    fn accuracy_filter_passes_good_config() {
        let be = OursBackend::halfhalf();
        let e = accuracy_filter(&TileConfig::default(), &be, 32).unwrap();
        assert!(e < 1e-6);
    }

    #[test]
    fn quantization_efficiency_bounds() {
        let cfg = TileConfig::default(); // 64x64 tiles
        assert_eq!(quantization_efficiency(&cfg, 128), 1.0);
        let e = quantization_efficiency(&cfg, 65); // 2x2 tiles for 65x65
        assert!(e < 0.3);
    }

    #[test]
    fn autotune_prefers_aligned_tiles() {
        let be = OursBackend::halfhalf();
        let best = autotune(&A100, Method::OursHalfHalf, &be, 256, 16, 5);
        assert!(!best.is_empty());
        // Top config should have perfect quantization at n=256 and
        // meaningful data reuse (not a 16-wide sliver).
        let (cfg, _) = best[0];
        assert_eq!(quantization_efficiency(&cfg, 256), 1.0);
        assert!(cfg.bm >= 64 && cfg.bn >= 64, "reuse should favor big tiles: {cfg:?}");
    }
}
