//! Block conjugate gradients for SPD `A·X = B` with the matvec on a
//! [`Backend`] (DESIGN.md §11).
//!
//! The `nrhs` right-hand sides iterate in lockstep — each column carries
//! its own `α_j`/`β_j` scalars, but the per-iteration matvec `Q = A·P`
//! is one real `n×n · n×nrhs` GEMM, which is exactly the shape the
//! serving stack batches, caches and shards. Host state is f64; the
//! matvec is normalized/rounded to f32 through [`matvec_f32`]. A column
//! whose recurrence residual reaches exactly zero is frozen (its `α`/`β`
//! become 0) instead of poisoning the others with a 0/0.
//!
//! Stall semantics: a non-finite iterate or a non-positive curvature
//! `pᵀA p` (which an inaccurate matvec can fabricate — fp16 regularly
//! does) ends the iteration with `stalled = true`; the trajectory
//! recorded so far IS the experiment's artifact.

use super::backend::Backend;
use super::mixed::{matvec_f32, residual_f64, Matvec};
use super::{SolveError, SolveReport, SolverConfig};
use crate::gemm::{Mat, MatF64};

/// Per-column dot products `⟨U_j, V_j⟩` of two equal-shape f64 blocks.
fn col_dots(u: &MatF64, v: &MatF64) -> Vec<f64> {
    let (n, nrhs) = (u.rows, u.cols);
    let mut out = vec![0.0f64; nrhs];
    for i in 0..n {
        for (j, o) in out.iter_mut().enumerate() {
            *o += u.get(i, j) * v.get(i, j);
        }
    }
    out
}

/// Conjugate gradients; see the module docs. `A` must be symmetric
/// positive definite for the method's theory to apply — the iteration
/// itself only requires the shapes to agree.
pub fn solve_cg(
    a: &Mat,
    b: &Mat,
    backend: &dyn Backend,
    cfg: &SolverConfig,
) -> Result<SolveReport, SolveError> {
    assert_eq!(a.rows, a.cols, "CG needs a square system");
    assert_eq!(a.cols, b.rows, "A and B shapes must agree");
    let (n, nrhs) = (a.rows, b.cols);
    let norm_b = b.fro_norm();

    let mut x = MatF64::zeros(n, nrhs);
    // X₀ = 0 ⇒ R₀ = B exactly.
    let mut r = MatF64 {
        rows: n,
        cols: nrhs,
        data: b.data.iter().map(|&v| v as f64).collect(),
    };
    let mut p = r.clone();
    let mut rho = col_dots(&r, &r);

    let mut report = SolveReport {
        x: MatF64::zeros(0, 0),
        resid: Vec::new(),
        true_resid: Vec::new(),
        iters: 0,
        converged: false,
        stalled: false,
        matvecs: 0,
    };
    if norm_b == 0.0 {
        // B = 0 ⇒ X = 0 is exact.
        report.x = x;
        report.converged = true;
        return Ok(report);
    }

    for _ in 1..=cfg.max_iters {
        let q = match matvec_f32(backend, a, &p)? {
            Matvec::Out(q) => q,
            // P = 0 means every column froze; the residual test below
            // already said "not converged", so this is a stall.
            Matvec::ZeroInput | Matvec::NonFinite => {
                report.stalled = true;
                break;
            }
        };
        report.matvecs += 1;

        // α_j = ρ_j / ⟨P_j, Q_j⟩; frozen columns (ρ_j = 0) keep α_j = 0.
        let pq = col_dots(&p, &q);
        let mut alpha = vec![0.0f64; nrhs];
        let mut lost_direction = false;
        for j in 0..nrhs {
            if rho[j] == 0.0 {
                continue;
            }
            let usable = pq[j].is_finite() && pq[j] > 0.0;
            if !usable {
                lost_direction = true;
                break;
            }
            alpha[j] = rho[j] / pq[j];
        }
        if lost_direction {
            report.stalled = true;
            break;
        }

        // X += P·diag(α);  R -= Q·diag(α).
        for i in 0..n {
            for j in 0..nrhs {
                x.set(i, j, x.get(i, j) + alpha[j] * p.get(i, j));
                r.set(i, j, r.get(i, j) - alpha[j] * q.get(i, j));
            }
        }
        report.iters += 1;

        // Both trajectories: the recurrence (drives `tol`) and the
        // FP64-verified truth (the stall detector).
        let rec = r.fro_norm() / norm_b;
        let (_, truth) = residual_f64(a, &x, b);
        report.resid.push(rec);
        report.true_resid.push(truth);
        if !rec.is_finite() {
            report.stalled = true;
            break;
        }
        if rec <= cfg.tol {
            report.converged = true;
            break;
        }

        // β_j = ρ'_j / ρ_j;  P = R + P·diag(β). Frozen columns stay 0.
        let rho_new = col_dots(&r, &r);
        let mut beta = vec![0.0f64; nrhs];
        for j in 0..nrhs {
            if rho[j] > 0.0 {
                beta[j] = rho_new[j] / rho[j];
            }
        }
        for i in 0..n {
            for j in 0..nrhs {
                p.set(i, j, r.get(i, j) + beta[j] * p.get(i, j));
            }
        }
        rho = rho_new;
    }

    report.x = x;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Method;
    use crate::matgen::spd_system;
    use crate::solver::DirectBackend;

    #[test]
    fn cg_converges_on_a_well_conditioned_spd_system() {
        let (a, _xt, b) = spd_system(32, 3, 100.0, 7);
        let be = DirectBackend::new(Method::Fp32Simt);
        let cfg = SolverConfig { tol: 1e-6, max_iters: 200 };
        let rep = solve_cg(&a, &b, &be, &cfg).unwrap();
        assert!(rep.converged, "final resid {}", rep.final_resid());
        assert!(!rep.stalled);
        assert!(rep.final_resid() <= 1e-6);
        // The verified residual agrees with the recurrence at this
        // accuracy level (well above the f32 matvec floor).
        assert!(rep.final_true_resid() < 1e-4, "true {}", rep.final_true_resid());
        assert_eq!(rep.matvecs, rep.iters);
        // Trajectories are per-iteration.
        assert_eq!(rep.resid.len(), rep.iters);
        assert_eq!(rep.true_resid.len(), rep.iters);
    }

    #[test]
    fn cg_trajectory_is_reproducible() {
        let (a, _xt, b) = spd_system(24, 2, 50.0, 9);
        let cfg = SolverConfig { tol: 1e-6, max_iters: 60 };
        let r1 = solve_cg(&a, &b, &DirectBackend::new(Method::OursHalfHalf), &cfg).unwrap();
        let r2 = solve_cg(&a, &b, &DirectBackend::new(Method::OursHalfHalf), &cfg).unwrap();
        assert!(r1.bit_identical(&r2));
    }

    #[test]
    fn cg_zero_rhs_is_trivially_exact() {
        let (a, _xt, _b) = spd_system(8, 2, 10.0, 1);
        let be = DirectBackend::new(Method::Fp32Simt);
        let rep = solve_cg(&a, &Mat::zeros(8, 2), &be, &SolverConfig::default()).unwrap();
        assert!(rep.converged);
        assert_eq!(rep.iters, 0);
        assert!(rep.x.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn cg_fixed_iteration_count_with_zero_tol() {
        let (a, _xt, b) = spd_system(16, 2, 10.0, 3);
        let be = DirectBackend::new(Method::OursHalfHalf);
        let cfg = SolverConfig { tol: 0.0, max_iters: 5 };
        let rep = solve_cg(&a, &b, &be, &cfg).unwrap();
        assert_eq!(rep.iters, 5);
        assert_eq!(rep.matvecs, 5);
        assert!(!rep.converged);
        assert!(!rep.stalled);
    }
}
