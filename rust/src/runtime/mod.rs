//! PJRT runtime: loads AOT-compiled artifacts (HLO text produced by
//! `python/compile/aot.py` from the L2 JAX model + L1 Pallas kernel) and
//! executes them from the Rust hot path. Python never runs here.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so the client
//! and every compiled executable live on one dedicated **engine thread**;
//! [`PjrtHandle`] is the cheap, cloneable, thread-safe front door. This also
//! serializes device access, which is what the single-device CPU PJRT
//! plugin wants anyway.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! **Offline gating (DESIGN.md §2):** the `xla` binding is an external
//! crate the offline image cannot fetch, so real PJRT execution sits behind
//! the `pjrt` cargo feature. Without it (the default) the engine thread is
//! a stub that answers every Load/Execute with an error; every caller on
//! the serving path ([`PjrtExecutor`]) already falls back to the bit-exact
//! simulator, so the default build loses no functionality that the offline
//! testbed could exercise. `anyhow` was replaced by the std-only
//! [`RuntimeError`] for the same reason.

use crate::coordinator::{BatchKey, Executor, GemmRequest, SimExecutor};
use crate::gemm::{Mat, Method};
use std::collections::HashMap;

/// Offline stand-in for the vendored `xla` crate: the `pjrt` engine below
/// compiles (and CI builds it) against this API-identical shim; swap in
/// the real crate by deleting this declaration (see `xla_shim.rs` docs).
#[cfg(feature = "pjrt")]
#[path = "xla_shim.rs"]
mod xla;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

/// Minimal string-backed error (`anyhow` is unavailable offline).
#[derive(Debug, Clone)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> RuntimeError {
        RuntimeError(msg.into())
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> RuntimeError {
        RuntimeError(e.to_string())
    }
}

/// Runtime-layer result.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Artifact naming scheme shared with `python/compile/aot.py`:
/// `ec_gemm_<variant>_<m>x<k>x<n>.hlo.txt`.
pub fn artifact_file(method: Method, m: usize, k: usize, n: usize) -> Option<String> {
    let variant = match method {
        Method::OursHalfHalf => "halfhalf",
        Method::OursTf32 => "tf32tf32",
        Method::Fp32Simt => "fp32",
        _ => return None,
    };
    Some(format!("ec_gemm_{variant}_{m}x{k}x{n}.hlo.txt"))
}

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
enum EngineMsg {
    /// Compile (and cache) the artifact at `path` under `key`.
    Load { key: String, path: PathBuf, reply: Sender<Result<()>> },
    /// Execute cached executable `key` with the given inputs; reply with
    /// row-major output data of `rows × cols`.
    Execute { key: String, inputs: Vec<Mat>, rows: usize, cols: usize, reply: Sender<Result<Mat>> },
    /// List cached keys.
    Loaded { reply: Sender<Vec<String>> },
    Shutdown,
}

#[cfg(feature = "pjrt")]
fn engine_main(rx: std::sync::mpsc::Receiver<EngineMsg>) {
    // Client creation failure is reported per-request (the thread keeps
    // serving so callers get errors rather than hangs).
    let client = xla::PjRtClient::cpu();
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    for msg in rx {
        match msg {
            EngineMsg::Load { key, path, reply } => {
                let r = (|| -> Result<()> {
                    let client = client
                        .as_ref()
                        .map_err(|e| RuntimeError::new(format!("PJRT client init failed: {e:?}")))?;
                    if cache.contains_key(&key) {
                        return Ok(());
                    }
                    let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                        RuntimeError::new(format!("parse {}: {e:?}", path.display()))
                    })?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| RuntimeError::new(format!("compile {key}: {e:?}")))?;
                    cache.insert(key, exe);
                    Ok(())
                })();
                let _ = reply.send(r);
            }
            EngineMsg::Execute { key, inputs, rows, cols, reply } => {
                let r = (|| -> Result<Mat> {
                    let exe = cache
                        .get(&key)
                        .ok_or_else(|| RuntimeError::new(format!("artifact {key} not loaded")))?;
                    let mut lits = Vec::with_capacity(inputs.len());
                    for (i, m) in inputs.iter().enumerate() {
                        lits.push(
                            xla::Literal::vec1(&m.data)
                                .reshape(&[m.rows as i64, m.cols as i64])
                                .map_err(|e| {
                                    RuntimeError::new(format!("reshape input {i}: {e:?}"))
                                })?,
                        );
                    }
                    let bufs = exe
                        .execute::<xla::Literal>(&lits)
                        .map_err(|e| RuntimeError::new(format!("execute: {e:?}")))?;
                    let lit = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| RuntimeError::new(format!("fetch: {e:?}")))?;
                    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
                    let out = lit
                        .to_tuple1()
                        .map_err(|e| RuntimeError::new(format!("untuple: {e:?}")))?;
                    let data = out
                        .to_vec::<f32>()
                        .map_err(|e| RuntimeError::new(format!("to_vec: {e:?}")))?;
                    if data.len() != rows * cols {
                        return Err(RuntimeError::new(format!(
                            "artifact {key}: got {} elements, want {rows}x{cols}",
                            data.len()
                        )));
                    }
                    Ok(Mat::from_vec(rows, cols, data))
                })();
                let _ = reply.send(r);
            }
            EngineMsg::Loaded { reply } => {
                let _ = reply.send(cache.keys().cloned().collect());
            }
            EngineMsg::Shutdown => break,
        }
    }
}

/// Stub engine for the default (offline) build: every Load/Execute fails
/// with a clear message; callers fall back to the simulator.
#[cfg(not(feature = "pjrt"))]
fn engine_main(rx: std::sync::mpsc::Receiver<EngineMsg>) {
    const MSG: &str = "PJRT disabled: build with `--features pjrt` and a vendored `xla` crate \
                       (offline default runs the bit-exact simulator instead; DESIGN.md §2)";
    for msg in rx {
        match msg {
            EngineMsg::Load { reply, .. } => {
                let _ = reply.send(Err(RuntimeError::new(MSG)));
            }
            EngineMsg::Execute { reply, .. } => {
                let _ = reply.send(Err(RuntimeError::new(MSG)));
            }
            EngineMsg::Loaded { reply } => {
                let _ = reply.send(Vec::new());
            }
            EngineMsg::Shutdown => break,
        }
    }
}

/// Thread-safe handle to the PJRT engine thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<EngineMsg>,
}

impl PjrtHandle {
    /// Spawn the engine thread. One per process is plenty.
    pub fn spawn() -> PjrtHandle {
        let (tx, rx) = channel();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(rx))
            .expect("spawn pjrt engine");
        PjrtHandle { tx }
    }

    /// Compile and cache an artifact file.
    pub fn load(&self, key: &str, path: &Path) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(EngineMsg::Load { key: key.into(), path: path.into(), reply })
            .map_err(|_| RuntimeError::new("engine thread gone"))?;
        rx.recv().map_err(|_| RuntimeError::new("engine thread died"))?
    }

    /// Execute a cached two-input GEMM artifact.
    pub fn execute(&self, key: &str, a: &Mat, b: &Mat) -> Result<Mat> {
        self.execute_multi(key, &[a, b], a.rows, b.cols)
    }

    /// Execute a cached artifact with any number of inputs (e.g. the
    /// 3-input MLP chain artifact). `rows × cols` is the expected output.
    pub fn execute_multi(
        &self,
        key: &str,
        inputs: &[&Mat],
        rows: usize,
        cols: usize,
    ) -> Result<Mat> {
        let (reply, rx) = channel();
        self.tx
            .send(EngineMsg::Execute {
                key: key.into(),
                inputs: inputs.iter().map(|m| (*m).clone()).collect(),
                rows,
                cols,
                reply,
            })
            .map_err(|_| RuntimeError::new("engine thread gone"))?;
        rx.recv().map_err(|_| RuntimeError::new("engine thread died"))?
    }

    pub fn loaded(&self) -> Vec<String> {
        let (reply, rx) = channel();
        if self.tx.send(EngineMsg::Loaded { reply }).is_err() {
            return Vec::new();
        }
        rx.recv().unwrap_or_default()
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(EngineMsg::Shutdown);
    }
}

/// Artifact registry: scans `artifacts/` and loads what it finds.
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    handle: PjrtHandle,
    available: Mutex<HashMap<String, PathBuf>>,
}

impl ArtifactRegistry {
    /// Scan `dir` for `*.hlo.txt` files (not yet compiled — compilation is
    /// lazy on first use).
    pub fn scan(dir: impl Into<PathBuf>, handle: PjrtHandle) -> Result<ArtifactRegistry> {
        let dir = dir.into();
        let mut available = HashMap::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(&dir)? {
                let p = entry?.path();
                if let Some(name) = p.file_name().and_then(|s| s.to_str()) {
                    if name.ends_with(".hlo.txt") {
                        available.insert(name.to_string(), p.clone());
                    }
                }
            }
        }
        Ok(ArtifactRegistry { dir, handle, available: Mutex::new(available) })
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.available.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.available.lock().unwrap().contains_key(name)
    }

    /// Ensure `name` is compiled; returns an executor key.
    pub fn ensure_loaded(&self, name: &str) -> Result<String> {
        let path = self
            .available
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| {
                RuntimeError::new(format!("no artifact named {name} in {}", self.dir.display()))
            })?;
        self.handle.load(name, &path)?;
        Ok(name.to_string())
    }

    pub fn handle(&self) -> &PjrtHandle {
        &self.handle
    }
}

/// Coordinator executor that runs batches through PJRT artifacts when one
/// exists for the (method, shape) key, falling back to the bit-exact
/// simulator otherwise. This is the production wiring: AOT kernels for the
/// shapes you serve, simulator for the long tail.
pub struct PjrtExecutor {
    registry: ArtifactRegistry,
    fallback: SimExecutor,
}

impl PjrtExecutor {
    pub fn new(registry: ArtifactRegistry) -> PjrtExecutor {
        PjrtExecutor { registry, fallback: SimExecutor::new() }
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }
}

impl Executor for PjrtExecutor {
    fn execute(&self, key: &BatchKey, reqs: &[GemmRequest]) -> Vec<Mat> {
        if let Some(name) = artifact_file(key.method, key.m, key.k, key.n) {
            if self.registry.has(&name) {
                if let Ok(k) = self.registry.ensure_loaded(&name) {
                    let mut out = Vec::with_capacity(reqs.len());
                    let mut ok = true;
                    for r in reqs {
                        match self.registry.handle().execute(&k, &r.a, &r.b) {
                            Ok(c) => out.push(c),
                            Err(_) => {
                                ok = false;
                                break;
                            }
                        }
                    }
                    if ok {
                        return out;
                    }
                }
            }
        }
        self.fallback.execute(key, reqs)
    }

    fn name(&self) -> &'static str {
        "pjrt+sim"
    }

    fn split_cache(&self) -> Option<std::sync::Arc<crate::coordinator::SplitCache>> {
        self.fallback.split_cache()
    }

    fn attach_split_cache(&self, cache: std::sync::Arc<crate::coordinator::SplitCache>) -> bool {
        // Splits only happen on the simulator fallback path; the cache
        // helps exactly there.
        self.fallback.attach_split_cache(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_naming() {
        assert_eq!(
            artifact_file(Method::OursHalfHalf, 64, 64, 64).unwrap(),
            "ec_gemm_halfhalf_64x64x64.hlo.txt"
        );
        assert_eq!(artifact_file(Method::Markidis, 8, 8, 8), None);
    }

    #[test]
    fn registry_scan_missing_dir_is_empty() {
        let h = PjrtHandle::spawn();
        let r = ArtifactRegistry::scan("/nonexistent-dir-xyz", h.clone()).unwrap();
        assert!(r.names().is_empty());
        assert!(r.ensure_loaded("nope.hlo.txt").is_err());
        h.shutdown();
    }

    #[test]
    fn stub_engine_reports_errors_not_hangs() {
        // Whether or not the pjrt feature is on, a missing artifact must be
        // an error; without the feature, loads of real paths error too.
        let h = PjrtHandle::spawn();
        assert!(h.execute("missing", &Mat::zeros(2, 2), &Mat::zeros(2, 2)).is_err());
        assert!(h.loaded().is_empty());
        h.shutdown();
    }

    // Full PJRT round-trip tests live in rust/tests/pjrt_e2e.rs and are
    // gated on `make artifacts` having produced the HLO files.
}
