//! §Perf hot-path bench: measured CPU wall-clock of (a) the solver matvec
//! hot path — reference simulator vs production engine (DESIGN.md §14) —
//! (b) the bit-exact simulated GEMM backends, (c) the split-amortized
//! batched path, (d) the PJRT artifact execution path, and (e) the
//! coordinator request loop. These are the numbers the performance pass in
//! EXPERIMENTS.md §Perf optimizes — real measurements, not GPU projections.
//!
//! Run:  `cargo bench --bench hotpath`
//! JSON: `cargo bench --bench hotpath -- --json > BENCH_hotpath.json`
//!
//! The matvec section is also a correctness gate: it asserts the engine
//! path was actually selected (`engine_runs()` advanced) and that its
//! output is bit-identical to the reference simulator — under `--smoke
//! --json` this is what CI's perf-smoke step runs.

use std::sync::Arc;
use tcec::bench_util::{bench, bench_params, json_array, json_mode, smoke, JsonObj, Table};
use tcec::coordinator::{GemmService, Policy, SimExecutor};
use tcec::gemm::{engine_runs, gemm_batched, BatchedOperands, Mat, Method, TileConfig, ENGINE_ID};
use tcec::matgen::urand;
use tcec::runtime::{ArtifactRegistry, PjrtHandle};

/// Bit-level equality (distinguishes -0.0 from +0.0; NaN bits compare
/// equal to themselves) — the engine's contract is bit-identity, not
/// numeric equality.
fn bits_eq(x: &Mat, y: &Mat) -> bool {
    x.rows == y.rows
        && x.cols == y.cols
        && x.data.iter().zip(&y.data).all(|(a, b)| a.to_bits() == b.to_bits())
}

fn main() {
    let cfg = TileConfig::default();
    let smoke = smoke();
    let json = json_mode();
    let (wu, mi, mt) = bench_params(1, 3, 0.3);

    // -- (a) solver matvec: reference simulator vs production engine -----
    //
    // The solver's per-iteration cost is one A (n x n) · p (n x 1) matvec
    // over prepared operands (the split is a cache hit after iteration
    // one — solver::DirectBackend), so both paths are timed from the same
    // prepared operands: this isolates the execution-core win the engine
    // claims (pack-once panels, arenas, hoisted dispatch).
    let matvec_sizes: &[usize] = if smoke { &[32] } else { &[256, 512] };
    let matvec_methods =
        [Method::OursHalfHalf, Method::OursTf32, Method::Fp32Simt, Method::OursBf16Triple];
    let mut matvec_rows: Vec<String> = Vec::new();
    if !json {
        println!("== solver matvec: reference simulator vs engine ({ENGINE_ID}) ==\n");
    }
    let mut t = Table::new(&["method", "n", "reference ms", "engine ms", "speedup", "bits"]);
    for method in matvec_methods {
        for &n in matvec_sizes {
            let a = urand(n, n, -1.0, 1.0, 21);
            let p = urand(n, 1, -1.0, 1.0, 22);
            let pa = method.prepare(&a);
            let pb = method.prepare(&p);
            let runs0 = engine_runs();
            let c_eng = method.run_prepared(&pa, &pb, &cfg);
            assert!(engine_runs() > runs0, "production engine path was not selected");
            let c_ref = method.run_prepared_reference(&pa, &pb, &cfg);
            let identical = bits_eq(&c_eng, &c_ref);
            assert!(identical, "engine output diverged from reference: {} n={n}", method.name());
            let s_ref = bench(
                || {
                    std::hint::black_box(method.run_prepared_reference(&pa, &pb, &cfg));
                },
                wu,
                mi,
                mt,
            );
            let s_eng = bench(
                || {
                    std::hint::black_box(method.run_prepared(&pa, &pb, &cfg));
                },
                wu,
                mi,
                mt,
            );
            let speedup = s_ref.median_s / s_eng.median_s;
            t.row(&[
                method.name().to_string(),
                n.to_string(),
                format!("{:.3}", s_ref.median_s * 1e3),
                format!("{:.3}", s_eng.median_s * 1e3),
                format!("{speedup:.2}x"),
                "identical".to_string(),
            ]);
            matvec_rows.push(
                JsonObj::new()
                    .str("method", method.name())
                    .int("n", n as u64)
                    .num("reference_ms", s_ref.median_s * 1e3)
                    .num("engine_ms", s_eng.median_s * 1e3)
                    .num("speedup", speedup)
                    .bool("bit_identical", identical)
                    .finish(),
            );
        }
    }
    if !json {
        t.print();
    }

    // -- (b) full-run backends (split + multiply, square operands) -------
    let backend_sizes: &[usize] = if smoke { &[16] } else { &[64, 128] };
    let mut backend_rows: Vec<String> = Vec::new();
    if !json {
        println!("\n== simulated GEMM backends (CPU wall-clock) ==\n");
    }
    let mut t = Table::new(&["method", "n", "median ms", "sim MFlop/s"]);
    for method in [
        Method::Fp32Simt,
        Method::Fp16Tc,
        Method::Markidis,
        Method::OursHalfHalf,
        Method::OursTf32,
    ] {
        for &n in backend_sizes {
            let a = urand(n, n, -1.0, 1.0, 1);
            let b = urand(n, n, -1.0, 1.0, 2);
            let s = bench(
                || {
                    std::hint::black_box(method.run(&a, &b, &cfg));
                },
                wu,
                mi,
                mt,
            );
            let mflops = 2.0 * (n as f64).powi(3) / s.median_s / 1e6;
            t.row(&[
                method.name().to_string(),
                n.to_string(),
                format!("{:.2}", s.median_s * 1e3),
                format!("{mflops:.1}"),
            ]);
            backend_rows.push(
                JsonObj::new()
                    .str("method", method.name())
                    .int("n", n as u64)
                    .num("median_ms", s.median_s * 1e3)
                    .num("sim_mflops", mflops)
                    .finish(),
            );
        }
    }
    if !json {
        t.print();
    }

    // -- (c) split-amortized batched GEMM (shared weight B) --------------
    let mut batched_rows: Vec<String> = Vec::new();
    if !json {
        println!("\n== split-amortized batched GEMM (shared weight B, same shape) ==\n");
    }
    let mut t = Table::new(&["method", "batch", "n", "loop ms", "batched ms", "speedup"]);
    let batches: &[usize] = if smoke { &[2] } else { &[4, 8] };
    for method in [Method::OursHalfHalf, Method::OursTf32, Method::Markidis] {
        for &batch in batches {
            let n = if smoke { 16 } else { 64 };
            let w = urand(n, n, -1.0, 1.0, 7);
            let pairs: Vec<(Mat, Mat)> =
                (0..batch).map(|i| (urand(n, n, -1.0, 1.0, 10 + i as u64), w.clone())).collect();
            let ops = BatchedOperands::from_mats(&pairs);
            // Per-element loop: every request re-splits both operands.
            let s_loop = bench(
                || {
                    for (a, b) in &pairs {
                        std::hint::black_box(method.run(a, b, &cfg));
                    }
                },
                wu,
                mi,
                mt,
            );
            // Batched path: each distinct operand (the shared weight in
            // particular) is split once for the whole batch.
            let s_batched = bench(
                || {
                    std::hint::black_box(gemm_batched(&ops, method, &cfg));
                },
                wu,
                mi,
                mt,
            );
            t.row(&[
                method.name().to_string(),
                batch.to_string(),
                n.to_string(),
                format!("{:.2}", s_loop.median_s * 1e3),
                format!("{:.2}", s_batched.median_s * 1e3),
                format!("{:.2}x", s_loop.median_s / s_batched.median_s),
            ]);
            batched_rows.push(
                JsonObj::new()
                    .str("method", method.name())
                    .int("batch", batch as u64)
                    .int("n", n as u64)
                    .num("loop_ms", s_loop.median_s * 1e3)
                    .num("batched_ms", s_batched.median_s * 1e3)
                    .num("speedup", s_loop.median_s / s_batched.median_s)
                    .finish(),
            );
        }
    }
    if !json {
        t.print();
    }

    if json {
        // One machine-readable document, nothing else on stdout.
        println!(
            "{}",
            JsonObj::new()
                .str("bench", "hotpath")
                .str("engine_id", ENGINE_ID)
                .bool("smoke", smoke)
                .bool("engine_selected", true)
                .bool("bit_identical", true)
                .raw("solver_matvec", &json_array(&matvec_rows))
                .raw("backends", &json_array(&backend_rows))
                .raw("batched", &json_array(&batched_rows))
                .finish()
        );
        return;
    }

    // -- (d) PJRT artifact execution (table mode only) -------------------
    println!("\n== PJRT artifact execution (needs `make artifacts`) ==\n");
    let handle = PjrtHandle::spawn();
    match ArtifactRegistry::scan("artifacts", handle.clone()) {
        Ok(reg) if !reg.names().is_empty() => {
            let mut t = Table::new(&["artifact", "median us", "GFlop/s"]);
            let names =
                ["ec_gemm_halfhalf_128x128x128.hlo.txt", "ec_gemm_fp32_128x128x128.hlo.txt"];
            for name in names {
                if !reg.has(name) {
                    continue;
                }
                reg.ensure_loaded(name).unwrap();
                let a = urand(128, 128, -1.0, 1.0, 3);
                let b = urand(128, 128, -1.0, 1.0, 4);
                let s = bench(
                    || {
                        std::hint::black_box(reg.handle().execute(name, &a, &b).unwrap());
                    },
                    3,
                    10,
                    0.5,
                );
                let gflops = 2.0 * 128f64.powi(3) / s.median_s / 1e9;
                t.row(&[
                    name.to_string(),
                    format!("{:.1}", s.median_s * 1e6),
                    format!("{gflops:.2}"),
                ]);
            }
            t.print();
        }
        _ => println!("(artifacts/ empty — skipped)"),
    }
    handle.shutdown();

    // -- (e) coordinator request loop (table mode only) ------------------
    let loop_n = if smoke { 16 } else { 64 };
    println!("\n== coordinator request loop (sim executor, {loop_n}x{loop_n}, batched) ==\n");
    let svc = GemmService::builder()
        .workers(2)
        .max_batch(8)
        .build(Arc::new(SimExecutor::new()));
    let n_req: u64 = if smoke { 8 } else { 64 };
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = (0..n_req)
        .map(|i| {
            let a = urand(loop_n, loop_n, -1.0, 1.0, i);
            let b = urand(loop_n, loop_n, -1.0, 1.0, i + 999);
            svc.call(a, b)
                .policy(Policy::Fp32Accuracy)
                .submit()
                .expect("admitted")
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let snap = svc.metrics().snapshot();
    println!("{n_req} requests in {dt:.3}s = {:.1} req/s, mean batch {:.2}, mean latency {:?}",
        n_req as f64 / dt, snap.mean_batch_size, snap.mean_latency);
    svc.shutdown();
}
