//! L2.5: the unified cost-based execution planner (DESIGN.md §9).
//!
//! Before this layer existed, the three decisions a GEMM request needs were
//! made in three unrelated places with three different inputs: the exponent
//! probe + method choice lived in `coordinator::policy` (a full O(mn) scan
//! per operand per request, on the dispatcher thread), tile selection was
//! hardcoded to `TileConfig::default()` in serving (leaving the Table 3
//! autotuner as dead weight), and `shard::plan` ran *inside* the sharded
//! executor, blind to what the router had decided. This module fuses them
//! into one [`ExecPlan`] from a single entry point:
//!
//! ```text
//! probe (sampled + ProbeCache) → admissible methods (policy × Fig. 11
//! class) → cost tie-break (perfmodel::projected_tflops) → tile memo
//! (autotune, per (method, n-bucket, gpu)) → shard gate (shard::plan over
//! the chosen tile) → ExecPlan { method, tile, shard, prescale, est_cost }
//! ```
//!
//! The stateless functions ([`plan`], [`select_method`], [`admissible`])
//! do one-shot planning; [`Planner`] adds the caches the serving hot path
//! needs ([`ProbeCache`], [`PlanCache`]) plus [`Planner::explain`], the
//! `tcec plan` CLI's view of the decision with every rejected alternative
//! and its estimated throughput. `coordinator::policy::route` is a thin
//! compat shim over [`select_method`], so legacy callers keep the exact
//! routing table they had.

pub mod cache;
pub(crate) mod lru;
pub mod probe;

pub use cache::{choose_tile, tile_is_safe, PlanCache, PlanSelector};
pub use probe::{probe_sampled, sampled_fingerprint, ProbeCache};

use crate::autotune::score;
use crate::coordinator::{Policy, RangeClass};
use crate::gemm::{Mat, Method, TileConfig};
use crate::perfmodel::{projected_tflops, GpuSpec, A100};
use crate::shard::{self, ShardConfig, ShardPlan};
use std::sync::Arc;

/// Planner policy knobs. `Default` is the serving configuration: autotuned
/// tiles (structural + score ranking), cached sampled probes, no sharding
/// (the service injects its own `ShardConfig` when sharding is on).
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// GPU model behind every cost estimate (and part of the tile-memo key).
    pub gpu: GpuSpec,
    /// Autotune tile shapes per (method, n-bucket); false pins
    /// `TileConfig::default()` for every plan.
    pub autotune_tiles: bool,
    /// Probe size of the autotuner's accuracy rule (Table 3 rule 3);
    /// 0 = structural filters + score ranking only.
    pub autotune_probe: usize,
    /// Probe size used to re-verify primed/cached tiles before first serve
    /// (`autotune::accuracy_filter`); 0 disables re-verification.
    pub verify_probe: usize,
    /// Sampled-probe cap: operands with more elements than this are
    /// classified (and fingerprinted) from this many strided samples;
    /// 0 = always exact. See `planner::probe` for the exactness trade.
    pub probe_samples: usize,
    /// Entry capacity of the [`ProbeCache`].
    pub probe_cache_entries: usize,
    /// Entry capacity of the [`PlanCache`]'s plan map.
    pub plan_cache_entries: usize,
    /// Shard planning config; `None` plans everything unsharded. The
    /// engine tile inside is overridden per-plan with the planned tile.
    pub shard: Option<ShardConfig>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            gpu: A100,
            autotune_tiles: true,
            autotune_probe: 0,
            verify_probe: 16,
            probe_samples: 4096,
            probe_cache_entries: 256,
            plan_cache_entries: 256,
            shard: None,
        }
    }
}

/// Everything the execution layers need to run one GEMM request: which
/// backend, under which tile shape, sharded or not, with the exponent
/// pre-scale hoisted or not — plus the cost estimate that justified it.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub method: Method,
    /// The tile configuration the engine executes under (autotuned per
    /// (method, n-bucket, gpu), or `TileConfig::default()`).
    pub tile: TileConfig,
    /// Shard grid for large problems (`None` = single-kernel path). Its
    /// `engine_tile` always equals `tile`.
    pub shard: Option<ShardPlan>,
    /// True when the method applies the exact exponent pre-scale before
    /// splitting (`halfhalf_prescale`); the shard path hoists it above the
    /// cut.
    pub prescale: bool,
    /// Tile-aware projected throughput of (method, tile) at this problem
    /// size (`autotune::score`, TFlop/s).
    pub est_cost_tflops: f64,
    /// The combined exponent-range class the probe assigned this request
    /// (Fig. 11) — `None` on forced-method plans, which skip the probe.
    /// Surfaced so the service can tally per-class traffic in `Metrics`
    /// (the telemetry layer's `range_class` counter).
    pub class: Option<RangeClass>,
    /// Ozaki slice count when this plan runs the multi-slice scheme
    /// ([`plan_ozaki`]); `None` on every direct-method plan. Cost for
    /// `Some(s)` plans is `ozaki_terms(s)`-scaled
    /// (`perfmodel::ozaki_projected_tflops`).
    pub ozaki_slices: Option<usize>,
}

impl ExecPlan {
    /// The `TileConfig` whose plain `Method::run` this plan's execution
    /// reproduces bit-for-bit: the planned tile itself, or — for sharded
    /// plans — the shard plan's equivalent tile (k-split widening).
    pub fn equivalent_tile(&self) -> TileConfig {
        match &self.shard {
            Some(sp) => sp.equivalent_tile(),
            None => self.tile,
        }
    }
}

/// Effective square dimension of an `m×k · k×n` problem for the (cubic)
/// cost model: `cbrt(m·n·k)`, at least 1.
pub fn effective_n(m: usize, n: usize, k: usize) -> usize {
    (((m * n * k) as f64).cbrt().round() as usize).max(1)
}

/// Tile-memo bucket: [`effective_n`] rounded up to a power of two, so the
/// autotuner runs once per size class instead of once per exact shape.
pub fn n_bucket(m: usize, n: usize, k: usize) -> usize {
    effective_n(m, n, k).next_power_of_two()
}

/// The methods that meet `policy`'s accuracy contract for inputs of
/// `class`, in accuracy-preference order. The cost model breaks ties
/// toward earlier entries, which is exactly the legacy `policy::route`
/// table — `route` is now a shim over [`select_method`] and its tests
/// pin that equivalence.
pub fn admissible(policy: Policy, class: RangeClass) -> &'static [Method] {
    match (policy, class) {
        // Bit-level FP32 reproducibility: Tensor Cores never admissible.
        (Policy::StrictFp32, _) => &[Method::Fp32Simt],
        // Non-finite or split-headroom-free inputs: SIMT only (Fig. 11
        // Type 4 has no correction story at either precision).
        (_, RangeClass::Extreme) => &[Method::Fp32Simt],
        (Policy::LowPrecisionOk, RangeClass::HalfHalfExact | RangeClass::HalfHalfDegraded) => {
            &[Method::Fp16Tc, Method::Tf32Tc, Method::Fp32Simt]
        }
        (Policy::LowPrecisionOk, RangeClass::NeedsWideExponent) => {
            &[Method::Tf32Tc, Method::Fp32Simt]
        }
        (Policy::Fp32Accuracy, RangeClass::HalfHalfExact) => {
            &[Method::OursHalfHalf, Method::OursTf32, Method::Fp32Simt]
        }
        // Degraded or wide range: tf32tf32 keeps FP32's exponent range
        // (Fig. 11: same accuracy as SIMT in all four types).
        (
            Policy::Fp32Accuracy,
            RangeClass::HalfHalfDegraded | RangeClass::NeedsWideExponent,
        ) => &[Method::OursTf32, Method::Fp32Simt],
    }
}

/// Pick the cheapest admissible method by projected throughput at
/// effective size `n_eff`, breaking ties toward the accuracy-preference
/// order of [`admissible`].
pub fn select_method(policy: Policy, class: RangeClass, gpu: &GpuSpec, n_eff: usize) -> Method {
    let cands = admissible(policy, class);
    let mut best = cands[0];
    let mut best_cost = projected_tflops(gpu, best, n_eff);
    for &m in &cands[1..] {
        let c = projected_tflops(gpu, m, n_eff);
        if c > best_cost {
            best = m;
            best_cost = c;
        }
    }
    best
}

/// Core plan construction once the method is fixed. `extreme` (non-finite
/// or split-headroom-free inputs) and degenerate shapes force the
/// unsharded path; degenerate shapes also carry a zero cost estimate
/// instead of feeding the cost model dimensions it would NaN on.
fn build_plan(
    method: Method,
    m: usize,
    n: usize,
    k: usize,
    class: Option<RangeClass>,
    cfg: &PlannerConfig,
    tiles: Option<&PlanCache>,
) -> ExecPlan {
    let extreme = class == Some(RangeClass::Extreme);
    let n_eff = effective_n(m, n, k);
    let bucket = n_bucket(m, n, k);
    let tile = match tiles {
        Some(pc) => pc.tile_for(method, bucket, cfg),
        None => choose_tile(method, bucket, cfg),
    };
    let degenerate = m == 0 || n == 0 || k == 0;
    let shard_plan = if extreme || degenerate {
        None
    } else {
        cfg.shard.as_ref().and_then(|sc| {
            let sc = ShardConfig { engine_tile: tile, gpu: cfg.gpu, ..sc.clone() };
            shard::plan(m, n, k, method, &sc)
        })
    };
    let est = if degenerate { 0.0 } else { score(&tile, &cfg.gpu, method, n_eff) };
    ExecPlan {
        method,
        tile,
        shard: shard_plan,
        prescale: method == Method::OursHalfHalfPre,
        est_cost_tflops: est,
        class,
        ozaki_slices: None,
    }
}

/// One point on the Ozaki accuracy-vs-cost frontier at inner dimension
/// `k`: a slice count with its provable error bound, term count, projected
/// throughput, and which accuracy classes it clears.
#[derive(Debug, Clone)]
pub struct OzakiPoint {
    /// Slice count `s` of this frontier point.
    pub slices: usize,
    /// Slice-pair GEMM terms the Tensor Core must run: `s(s+1)/2`.
    pub terms: usize,
    /// Provable normalized error bound (`analysis::ozaki_bound`).
    pub bound: f64,
    /// Projected saturation throughput at this term count
    /// (`perfmodel::ozaki_projected_tflops`).
    pub est_tflops: f64,
    /// True when `bound` clears the fp32 accuracy class
    /// (`analysis::fp32_class_tol`).
    pub admissible_fp32: bool,
    /// True when `bound` clears the fp64 accuracy class
    /// (`analysis::fp64_class_tol`).
    pub admissible_fp64: bool,
}

/// The Ozaki accuracy-vs-cost frontier at inner dimension `k`: one
/// [`OzakiPoint`] per slice count `1..=max_s`, monotone in both accuracy
/// (bound shrinks) and cost (throughput shrinks). The `tcec plan
/// --target` view, and what [`plan_ozaki`] selects on.
pub fn ozaki_frontier(gpu: &GpuSpec, k: usize, max_s: usize) -> Vec<OzakiPoint> {
    use crate::analysis::{fp32_class_tol, fp64_class_tol, ozaki_bound};
    (1..=max_s.max(1))
        .map(|s| {
            let bound = ozaki_bound(k, s);
            OzakiPoint {
                slices: s,
                terms: crate::gemm::ozaki_terms(s),
                bound,
                est_tflops: crate::perfmodel::ozaki_projected_tflops(gpu, s),
                admissible_fp32: bound <= fp32_class_tol(k),
                admissible_fp64: bound <= fp64_class_tol(k),
            }
        })
        .collect()
}

/// Plan a multi-slice Ozaki execution for an `m×k · k×n` problem: the
/// cheapest slice count whose provable bound meets `target`'s accuracy
/// class (minimal admissible `s` — cost is strictly decreasing in terms,
/// so minimal `s` is cheapest), falling back to the significand-coverage
/// count `target.slices(k)` if the bound alone never clears the class
/// within the search window. `SliceTarget::Slices(s)` pins `s` exactly.
/// The plan's `method` records the underlying TC primitive (`Fp16Tc`);
/// `ozaki_slices` is what the executor dispatches on.
pub fn plan_ozaki(
    m: usize,
    n: usize,
    k: usize,
    target: crate::gemm::SliceTarget,
    cfg: &PlannerConfig,
) -> ExecPlan {
    use crate::analysis::{fp32_class_tol, fp64_class_tol, ozaki_bound};
    use crate::gemm::SliceTarget;
    let coverage = target.slices(k);
    let s = match target {
        SliceTarget::Slices(s) => s.clamp(1, 64),
        SliceTarget::Fp32 | SliceTarget::Fp64 => {
            let tol =
                if target == SliceTarget::Fp32 { fp32_class_tol(k) } else { fp64_class_tol(k) };
            (1..=coverage).find(|&s| ozaki_bound(k, s) <= tol).unwrap_or(coverage)
        }
    };
    let degenerate = m == 0 || n == 0 || k == 0;
    let est = if degenerate {
        0.0
    } else {
        crate::perfmodel::ozaki_projected_tflops(&cfg.gpu, s)
    };
    ExecPlan {
        method: Method::Fp16Tc,
        tile: TileConfig::default(),
        shard: None,
        prescale: false,
        est_cost_tflops: est,
        class: None,
        ozaki_slices: Some(s),
    }
}

/// One-shot planning without a [`Planner`]'s caches: probe class and
/// policy in, a complete [`ExecPlan`] out. The single entry point behind
/// which the router, the tile memo and the shard gate were unified —
/// serving goes through [`Planner::plan_request`] for the cached version.
pub fn plan(
    m: usize,
    n: usize,
    k: usize,
    class: RangeClass,
    policy: Policy,
    cfg: &PlannerConfig,
) -> ExecPlan {
    let method = select_method(policy, class, &cfg.gpu, effective_n(m, n, k));
    build_plan(method, m, n, k, Some(class), cfg, None)
}

/// One-shot planning with the method pinned (`force_method`, benches,
/// shard-internal sub-plans): tile memo and shard gate still apply.
pub fn plan_for_method(
    method: Method,
    m: usize,
    n: usize,
    k: usize,
    cfg: &PlannerConfig,
) -> ExecPlan {
    build_plan(method, m, n, k, None, cfg, None)
}

/// One rejected (or tied) candidate in an [`Explain`] report.
#[derive(Debug, Clone)]
pub struct Alternative {
    pub method: Method,
    /// The cost-model estimate that ranked it (TFlop/s at `effective_n`).
    pub projected_tflops: f64,
    /// False when the (policy, class) pair rules the method out before
    /// cost is even consulted.
    pub admissible: bool,
    pub why: String,
}

/// The `tcec plan` view of one planning decision: the chosen plan plus
/// every other method with its estimated throughput and rejection reason.
#[derive(Debug, Clone)]
pub struct Explain {
    pub class: RangeClass,
    pub policy: Policy,
    pub chosen: Arc<ExecPlan>,
    /// Every non-chosen method, admissible candidates first, each ranked
    /// by projected TFlop/s.
    pub rejected: Vec<Alternative>,
}

/// The stateful planner: one instance per service, owning the probe and
/// plan caches. All methods take `&self`; the caches are internally
/// locked, so a `Planner` can be shared across dispatcher and workers in
/// an `Arc`.
#[derive(Debug)]
pub struct Planner {
    cfg: PlannerConfig,
    probes: ProbeCache,
    plans: PlanCache,
}

impl Planner {
    /// A planner with freshly constructed (empty) probe and plan caches.
    pub fn new(cfg: PlannerConfig) -> Planner {
        let probes = ProbeCache::new(cfg.probe_cache_entries.max(1), cfg.probe_samples);
        let plans = PlanCache::new(cfg.plan_cache_entries.max(1));
        Planner { cfg, probes, plans }
    }

    /// The configuration this planner was built with.
    pub fn config(&self) -> &PlannerConfig {
        &self.cfg
    }

    /// The shape-classification cache (exposed for metrics and tests).
    pub fn probe_cache(&self) -> &ProbeCache {
        &self.probes
    }

    /// The tile-plan cache (exposed for metrics and tests).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.plans
    }

    /// Classify one operand's exponent range through the probe cache.
    pub fn classify(&self, m: &Mat) -> RangeClass {
        self.probes.classify(m)
    }

    /// The serving entry point: classify both operands (cached, sampled),
    /// combine with the worse class (one bad operand is enough — the
    /// paper's Type 2 case), and plan under `policy`.
    pub fn plan_request(&self, a: &Mat, b: &Mat, policy: Policy) -> Arc<ExecPlan> {
        let class = self.classify(a).max(self.classify(b));
        self.plan_routed(a.rows, b.cols, a.cols, class, policy)
    }

    /// Cached planning for an already-classified request.
    pub fn plan_routed(
        &self,
        m: usize,
        n: usize,
        k: usize,
        class: RangeClass,
        policy: Policy,
    ) -> Arc<ExecPlan> {
        self.plans.get_or_plan(m, n, k, PlanSelector::Routed { class, policy }, || {
            let method = select_method(policy, class, &self.cfg.gpu, effective_n(m, n, k));
            build_plan(method, m, n, k, Some(class), &self.cfg, Some(&self.plans))
        })
    }

    /// Cached planning with the method pinned (the `force_method` path).
    pub fn plan_for_method(&self, method: Method, m: usize, n: usize, k: usize) -> Arc<ExecPlan> {
        self.plans.get_or_plan(m, n, k, PlanSelector::Forced { method }, || {
            build_plan(method, m, n, k, None, &self.cfg, Some(&self.plans))
        })
    }

    /// Explain-style planning: the chosen plan plus every rejected
    /// alternative with its estimated throughput (the `tcec plan` output).
    pub fn explain(
        &self,
        m: usize,
        n: usize,
        k: usize,
        class: RangeClass,
        policy: Policy,
    ) -> Explain {
        let chosen = self.plan_routed(m, n, k, class, policy);
        let n_eff = effective_n(m, n, k);
        let chosen_cost = projected_tflops(&self.cfg.gpu, chosen.method, n_eff);
        let adm = admissible(policy, class);
        let mut rejected = Vec::new();
        for &mm in &Method::ALL {
            if mm == chosen.method {
                continue;
            }
            let cost = projected_tflops(&self.cfg.gpu, mm, n_eff);
            let (is_adm, why) = if adm.contains(&mm) {
                (
                    true,
                    format!(
                        "admissible; projected {cost:.1} TFlop/s does not beat {chosen_cost:.1}"
                    ),
                )
            } else {
                (false, format!("inadmissible under {policy:?} for {class:?} inputs"))
            };
            rejected.push(Alternative {
                method: mm,
                projected_tflops: cost,
                admissible: is_adm,
                why,
            });
        }
        rejected.sort_by(|x, y| {
            y.admissible
                .cmp(&x.admissible)
                .then(y.projected_tflops.total_cmp(&x.projected_tflops))
        });
        Explain { class, policy, chosen, rejected }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::urand;

    #[test]
    fn select_method_reproduces_legacy_route_table() {
        // The exact (policy, class) → method table `policy::route`
        // encoded before it became a shim. Cost ties break toward the
        // accuracy-preference order, so this holds at every size.
        use Method::*;
        use Policy::*;
        use RangeClass::*;
        let table = [
            (Fp32Accuracy, HalfHalfExact, OursHalfHalf),
            (Fp32Accuracy, HalfHalfDegraded, OursTf32),
            (Fp32Accuracy, NeedsWideExponent, OursTf32),
            (Fp32Accuracy, Extreme, Fp32Simt),
            (LowPrecisionOk, HalfHalfExact, Fp16Tc),
            (LowPrecisionOk, HalfHalfDegraded, Fp16Tc),
            (LowPrecisionOk, NeedsWideExponent, Tf32Tc),
            (LowPrecisionOk, Extreme, Fp32Simt),
            (StrictFp32, HalfHalfExact, Fp32Simt),
            (StrictFp32, NeedsWideExponent, Fp32Simt),
        ];
        // Every power of two through paper scale, plus odd off-bucket
        // sizes, so a cost-model crossover at ANY size would be caught —
        // `policy::route` (the shim) inherits this table verbatim.
        let sweep = (0..=14).map(|p| 1usize << p).chain([3usize, 37, 100, 1000, 5000]);
        for n_eff in sweep {
            for &(policy, class, want) in &table {
                assert_eq!(
                    select_method(policy, class, &A100, n_eff),
                    want,
                    "({policy:?}, {class:?}) at n_eff {n_eff}"
                );
            }
        }
    }

    #[test]
    fn plan_shards_only_above_threshold_and_with_config() {
        let unsharded = PlannerConfig::default();
        let p = plan(512, 512, 512, RangeClass::HalfHalfExact, Policy::Fp32Accuracy, &unsharded);
        assert!(p.shard.is_none(), "no shard config → no shard plan");
        let sharded = PlannerConfig {
            shard: Some(ShardConfig { workers: 4, ..ShardConfig::default() }),
            ..PlannerConfig::default()
        };
        let p = plan(512, 512, 512, RangeClass::HalfHalfExact, Policy::Fp32Accuracy, &sharded);
        let sp = p.shard.as_ref().expect("512³ clears the default threshold");
        assert_eq!(sp.engine_tile, p.tile, "shard grid must align to the planned tile");
        let small = plan(32, 32, 32, RangeClass::HalfHalfExact, Policy::Fp32Accuracy, &sharded);
        assert!(small.shard.is_none(), "below threshold stays unsharded");
    }

    #[test]
    fn extreme_inputs_plan_fp32_simt_unsharded() {
        // Even with sharding configured and the threshold at zero, extreme
        // (non-finite / headroom-free) inputs take the conservative path.
        let cfg = PlannerConfig {
            shard: Some(ShardConfig { workers: 4, min_flops: 0, ..ShardConfig::default() }),
            ..PlannerConfig::default()
        };
        for policy in [Policy::Fp32Accuracy, Policy::LowPrecisionOk, Policy::StrictFp32] {
            let p = plan(256, 256, 256, RangeClass::Extreme, policy, &cfg);
            assert_eq!(p.method, Method::Fp32Simt, "{policy:?}");
            assert!(p.shard.is_none(), "{policy:?}: extreme inputs must not shard");
        }
        // End-to-end: a non-finite operand classifies Extreme through the
        // planner's sampled probe and lands on the same plan.
        let planner = Planner::new(cfg);
        let mut inf = urand(16, 16, -1.0, 1.0, 1);
        inf.set(3, 3, f32::NEG_INFINITY);
        let good = urand(16, 16, -1.0, 1.0, 2);
        let p = planner.plan_request(&inf, &good, Policy::Fp32Accuracy);
        assert_eq!(p.method, Method::Fp32Simt);
        assert!(p.shard.is_none());
        // Huge-magnitude (e = 127) inputs too.
        let big = urand(16, 16, 2.0e38, 3.0e38, 3);
        let p = planner.plan_request(&big, &good, Policy::LowPrecisionOk);
        assert_eq!(p.method, Method::Fp32Simt);
    }

    #[test]
    fn degenerate_shapes_plan_without_panicking() {
        let cfg = PlannerConfig {
            shard: Some(ShardConfig { workers: 4, min_flops: 0, ..ShardConfig::default() }),
            ..PlannerConfig::default()
        };
        for (m, n, k) in [(0, 16, 16), (16, 0, 16), (16, 16, 0), (0, 0, 0)] {
            let p = plan(m, n, k, RangeClass::HalfHalfExact, Policy::Fp32Accuracy, &cfg);
            assert!(p.shard.is_none(), "({m},{n},{k}): trivial plans never shard");
            assert_eq!(p.est_cost_tflops, 0.0, "({m},{n},{k}): zero work, zero cost");
            assert!(p.tile.bm > 0 && p.tile.bk > 0, "({m},{n},{k}): tile must stay runnable");
            // And the planned single-kernel path actually executes.
            let a = Mat::zeros(m, k);
            let b = Mat::zeros(k, n);
            let c = p.method.run(&a, &b, &p.tile);
            assert_eq!((c.rows, c.cols), (m, n));
        }
    }

    #[test]
    fn planner_caches_plans_and_probes() {
        let planner = Planner::new(PlannerConfig::default());
        let w = urand(24, 24, -1.0, 1.0, 40);
        let a0 = urand(24, 24, -1.0, 1.0, 41);
        let a1 = urand(24, 24, -1.0, 1.0, 42);
        let p0 = planner.plan_request(&a0, &w, Policy::Fp32Accuracy);
        let p1 = planner.plan_request(&a1, &w, Policy::Fp32Accuracy);
        assert!(Arc::ptr_eq(&p0, &p1), "same shape/class/policy must reuse the plan");
        // a0, a1 and w each probed once; w hit on the second request.
        assert_eq!(planner.probe_cache().misses(), 3);
        assert_eq!(planner.probe_cache().hits(), 1);
        assert_eq!(planner.plan_cache().misses(), 1);
        assert_eq!(planner.plan_cache().hits(), 1);
    }

    #[test]
    fn explain_reports_rejections_with_costs() {
        let planner = Planner::new(PlannerConfig::default());
        let ex =
            planner.explain(1024, 1024, 1024, RangeClass::HalfHalfExact, Policy::Fp32Accuracy);
        assert_eq!(ex.chosen.method, Method::OursHalfHalf);
        // Every other method appears with a cost and a reason.
        assert_eq!(ex.rejected.len(), Method::ALL.len() - 1);
        assert!(ex.rejected.iter().all(|r| r.projected_tflops.is_finite()));
        assert!(ex.rejected.iter().all(|r| !r.why.is_empty()));
        // Admissible-but-slower candidates rank first.
        assert!(ex.rejected[0].admissible);
        assert_eq!(ex.rejected[0].method, Method::OursTf32);
        let inadmissible = ex.rejected.iter().filter(|r| !r.admissible).count();
        assert!(inadmissible >= 2, "at least two inadmissible alternatives reported");
    }

    #[test]
    fn ozaki_frontier_is_monotone_and_gates_classes() {
        use crate::gemm::SliceTarget;
        let pts = ozaki_frontier(&A100, 512, 8);
        assert_eq!(pts.len(), 8);
        for w in pts.windows(2) {
            assert!(w[1].bound < w[0].bound, "accuracy improves with s");
            assert!(w[1].est_tflops < w[0].est_tflops, "cost grows with s");
            assert!(w[1].terms > w[0].terms);
        }
        // k=512 pins (β=8 post-fix): fp32 opens at s=3, fp64 at s=7.
        assert!(!pts[1].admissible_fp32 && pts[2].admissible_fp32);
        assert!(!pts[5].admissible_fp64 && pts[6].admissible_fp64);
        // plan_ozaki picks the minimal admissible point per target.
        let cfg = PlannerConfig::default();
        let p32 = plan_ozaki(64, 64, 512, SliceTarget::Fp32, &cfg);
        assert_eq!(p32.ozaki_slices, Some(3));
        let p64 = plan_ozaki(64, 64, 512, SliceTarget::Fp64, &cfg);
        assert_eq!(p64.ozaki_slices, Some(7));
        assert!(p64.est_cost_tflops < p32.est_cost_tflops, "fp64 costs more");
        let pinned = plan_ozaki(64, 64, 512, SliceTarget::Slices(5), &cfg);
        assert_eq!(pinned.ozaki_slices, Some(5));
        // Direct-method plans never carry a slice count.
        let direct = plan(64, 64, 512, RangeClass::HalfHalfExact, Policy::Fp32Accuracy, &cfg);
        assert_eq!(direct.ozaki_slices, None);
    }

    #[test]
    fn forced_plans_reuse_the_tile_memo() {
        let planner = Planner::new(PlannerConfig::default());
        let routed =
            planner.plan_routed(64, 64, 64, RangeClass::HalfHalfExact, Policy::Fp32Accuracy);
        let forced = planner.plan_for_method(Method::OursHalfHalf, 64, 64, 64);
        assert_eq!(routed.method, forced.method);
        assert_eq!(routed.tile, forced.tile, "both selectors share the (method, bucket) tile");
    }
}
