//! Ozaki-scheme GEMM (Ozaki et al. 2012; Mukunoki et al. 2020 on Tensor
//! Cores) — the related-work baseline the paper positions against: an
//! *error-free transformation* that splits operands into slices whose
//! pairwise products accumulate **exactly** in the Tensor-Core datapath,
//! recovering FP32 (or better) accuracy at the cost of `s(s+1)/2`
//! low-precision GEMMs. The paper's point: for FP32, this is slower than
//! both cuBLAS SGEMM and their 3-term correction — which this module's
//! term-count model reproduces.
//!
//! Slicing: row `i` of A is scaled by `σ_i = 2^(max exponent of the row)`;
//! each slice keeps `β` significand bits on the grid `σ_i · 2^{-β(j+1)}`,
//! extracted by truncation so `a = Σ_j s_j` exactly after `s` slices cover
//! the 24-bit significand. `β` is chosen so a k-long dot product of two
//! β-bit slices fits the 25-bit TC accumulator **exactly**:
//! `2β + ceil(log2 k) ≤ 25`. B is sliced column-wise symmetrically.

use super::matrix::Mat;
use crate::fp::exp2i;
use crate::fp::mantissa::exponent_of;
use crate::fp::rounding::narrow_to_f32;
use crate::tcsim::{mma_tile_zero_into, MmaConfig};

/// Largest per-slice significand width β such that slice-pair dot products
/// of length `k` are exact in the 25-bit Tensor-Core accumulator.
pub fn slice_bits(k: usize) -> u32 {
    let logk = (usize::BITS - k.max(1).leading_zeros()) as u32; // ceil(log2 k)+1-ish, safe side
    ((25u32.saturating_sub(logk)) / 2).clamp(1, 11)
}

/// Number of slices needed to cover FP32's 24-bit significand at width β.
pub fn slices_for_fp32(beta: u32) -> usize {
    24u32.div_ceil(beta) as usize
}

/// Row- (or column-) scaled truncation slicing. Returns `s` matrices whose
/// sum reconstructs `m` exactly (up to the dropped tail below slice `s`),
/// plus the per-row (or per-column) scales.
fn slice_matrix(m: &Mat, beta: u32, s: usize, row_wise: bool) -> (Vec<Mat>, Vec<f64>) {
    let outer = if row_wise { m.rows } else { m.cols };
    let mut scales = vec![0.0f64; outer];
    for o in 0..outer {
        let mut max_e = i32::MIN;
        let n_inner = if row_wise { m.cols } else { m.rows };
        for i in 0..n_inner {
            let v = if row_wise { m.get(o, i) } else { m.get(i, o) };
            if v != 0.0 {
                max_e = max_e.max(exponent_of(v));
            }
        }
        scales[o] = if max_e == i32::MIN { 1.0 } else { exp2i(max_e + 1) };
    }
    let mut slices = vec![Mat::zeros(m.rows, m.cols); s];
    for i in 0..m.rows {
        for j in 0..m.cols {
            let o = if row_wise { i } else { j };
            let sigma = scales[o];
            let mut r = m.get(i, j) as f64;
            for (idx, sl) in slices.iter_mut().enumerate() {
                let g = sigma * exp2i(-((beta as i32) * (idx as i32 + 1)));
                let q = (r / g).trunc() * g; // truncation toward zero: exact
                // tclint: allow(lossy-cast) -- q sits on the beta-bit slice grid by construction, so the cast is exact
                sl.set(i, j, q as f32);
                r -= q;
            }
        }
    }
    (slices, scales)
}

/// Ozaki-scheme GEMM: `C = Σ_{p+q < s} A_p · B_q` with every slice-pair
/// GEMM run on the (simulated) Tensor Core — each is *exact* by the β
/// choice, so all error comes from the dropped `p+q ≥ s` tail and the
/// final FP32 store. `s = slices_for_fp32(slice_bits(k))` recovers full
/// FP32 accuracy.
pub fn ozaki_gemm(a: &Mat, b: &Mat, s: usize) -> Mat {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let beta = slice_bits(k);
    let (a_sl, _) = slice_matrix(a, beta, s, true);
    let (b_sl, _) = slice_matrix(b, beta, s, false);
    let mut acc = vec![0.0f64; m * n];
    let mut tile = vec![0.0f32; m * n];
    let mut terms = 0usize;
    for p in 0..s {
        for q in 0..s {
            if p + q >= s {
                continue; // tail below the FP32 LSB, dropped (à la eq. 24)
            }
            terms += 1;
            // Slice values are on a coarse power-of-two grid: the TC GEMM
            // of a slice pair is exact (validated in tests), so a single
            // full-k MMA per pair suffices.
            mma_tile_zero_into(
                &mut tile,
                &a_sl[p].data,
                &b_sl[q].data,
                m,
                n,
                k,
                MmaConfig::TENSOR_CORE,
            );
            for (dst, &t) in acc.iter_mut().zip(tile.iter()) {
                *dst += t as f64; // exact: f64 accumulation across terms
            }
        }
    }
    debug_assert_eq!(terms, s * (s + 1) / 2);
    // The one genuinely lossy step (the "final FP32 store" above), routed
    // through the sanctioned fp:: narrowing site.
    Mat::from_vec(m, n, acc.iter().map(|&x| narrow_to_f32(x)).collect())
}

/// GEMM-term count of the scheme (performance-model input): s(s+1)/2.
pub fn ozaki_terms(s: usize) -> usize {
    s * (s + 1) / 2
}

/// Projected throughput of Ozaki-on-TC for FP32 accuracy (the paper's
/// related-work claim: slower than cuBLAS SGEMM for FP32): TC peak divided
/// by the term count, with corrected-kernel-class utilization.
pub fn projected_tflops_fp32(gpu: &crate::perfmodel::GpuSpec, k: usize) -> f64 {
    let s = slices_for_fp32(slice_bits(k));
    gpu.fp16_tc_tflops / ozaki_terms(s) as f64 * 0.45
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{gemm_f64, relative_residual, Method, TileConfig};
    use crate::matgen::urand;

    #[test]
    fn beta_and_slice_counts() {
        // k = 1024: ceil-ish log2 = 11 -> beta = 7 -> 4 slices for 24 bits.
        let b = slice_bits(1024);
        assert!((6..=8).contains(&b), "beta {b}");
        assert_eq!(slices_for_fp32(6), 4);
        assert_eq!(slices_for_fp32(8), 3);
        assert_eq!(ozaki_terms(4), 10);
    }

    #[test]
    fn slicing_reconstructs_exactly() {
        let m = urand(16, 16, -1.0, 1.0, 3);
        let beta = 6;
        let s = slices_for_fp32(beta) + 1; // one extra slice: full coverage
        let (slices, _) = slice_matrix(&m, beta, s, true);
        for i in 0..16 {
            for j in 0..16 {
                let sum: f64 = slices.iter().map(|sl| sl.get(i, j) as f64).sum();
                let err = (sum - m.get(i, j) as f64).abs();
                // Remaining tail is below sigma * 2^-(beta*s) <= 2^-29.
                assert!(err <= m.get(i, j).abs() as f64 * exp2i(-28) + 1e-300, "err {err:e}");
            }
        }
    }

    #[test]
    fn slice_pair_products_exact_in_tc() {
        // The scheme's defining invariant: a slice-pair GEMM on the RZ
        // Tensor Core equals the f64 reference bit-for-bit (no rounding
        // ever fires inside the accumulator).
        let k = 256;
        let a = urand(8, k, -1.0, 1.0, 5);
        let b = urand(k, 8, -1.0, 1.0, 6);
        let beta = slice_bits(k);
        let (a_sl, _) = slice_matrix(&a, beta, 2, true);
        let (b_sl, _) = slice_matrix(&b, beta, 2, false);
        let mut d = vec![0.0f32; 64];
        mma_tile_zero_into(&mut d, &a_sl[0].data, &b_sl[0].data, 8, 8, k, MmaConfig::TENSOR_CORE);
        let r = gemm_f64(&a_sl[0], &b_sl[0]);
        for (got, want) in d.iter().zip(r.data.iter()) {
            assert_eq!(*got as f64, *want, "slice GEMM not exact");
        }
    }

    #[test]
    fn full_scheme_reaches_fp32_accuracy() {
        let k = 512;
        let a = urand(16, k, -1.0, 1.0, 7);
        let b = urand(k, 16, -1.0, 1.0, 8);
        let r = gemm_f64(&a, &b);
        let s = slices_for_fp32(slice_bits(k));
        let c = ozaki_gemm(&a, &b, s);
        let e = relative_residual(&r, &c);
        let simt = relative_residual(&r, &Method::Fp32Simt.run(&a, &b, &TileConfig::default()));
        // Error-free transformation: at least FP32-level (usually better —
        // only the final store rounds).
        assert!(e <= simt * 1.5 + 1e-12, "ozaki {e} vs simt {simt}");
    }

    #[test]
    fn paper_claim_slower_than_sgemm_for_fp32() {
        // The reason the paper's method exists: Ozaki-on-TC needs ~10 TC
        // GEMMs for FP32, landing below both cuBLAS SGEMM and ours.
        use crate::perfmodel::{peak_tflops, A100};
        let oz = projected_tflops_fp32(&A100, 4096);
        let simt = peak_tflops(&A100, Method::Fp32Simt);
        let ours = peak_tflops(&A100, Method::OursHalfHalf);
        assert!(oz < simt, "ozaki {oz} vs simt {simt}");
        assert!(oz < ours / 2.0, "ozaki {oz} vs ours {ours}");
    }
}
