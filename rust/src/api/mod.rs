//! L3-front: the versioned client API of the GEMM service (DESIGN.md §10).
//!
//! This is the **one supported client surface**. Everything a caller can
//! observe is expressed in the types:
//!
//! * [`Client`] / [`Session`] — shared handle over a running service, and
//!   a per-tenant bundle of call defaults (policy, deadline, priority,
//!   tag);
//! * [`GemmCall`] — the per-request builder
//!   (`.policy() .deadline() .priority() .tag()`), terminating in
//!   [`GemmCall::submit`] → [`Ticket`];
//! * [`Ticket`] — the outstanding-call handle:
//!   [`wait`](Ticket::wait) / [`wait_timeout`](Ticket::wait_timeout) /
//!   [`try_get`](Ticket::try_get) / [`cancel`](Ticket::cancel);
//! * [`GemmResult`] = `Result<GemmOutcome, ServiceError>` — every reply is
//!   fallible, and [`ServiceError`] enumerates exactly how a request can
//!   die (rejected, expired, cancelled, executor failure, shutdown,
//!   invalid shape). No hung channels, no panics across the API boundary.
//! * [`ServiceBuilder`] — the supported way to configure and start the
//!   service (`GemmService::builder()`).
//!
//! (The pre-PR-4 `GemmService::submit` / `gemm_blocking` raw-channel
//! shims and the `GemmResponse` alias are gone — this layer is the only
//! way in.)
//!
//! # Example: deadline, cancellation, structured failure
//!
//! ```
//! use std::sync::Arc;
//! use std::time::Duration;
//! use tcec::api::ServiceError;
//! use tcec::coordinator::{GemmService, Policy, SimExecutor};
//! use tcec::matgen::urand;
//!
//! let client = GemmService::builder()
//!     .workers(1)
//!     .queue_cap(64)
//!     .client(Arc::new(SimExecutor::new()));
//!
//! // A call that cannot run is rejected synchronously, in the type.
//! let err = client
//!     .call(urand(8, 4, -1.0, 1.0, 1), urand(8, 8, -1.0, 1.0, 2))
//!     .submit()
//!     .unwrap_err();
//! assert!(matches!(err, ServiceError::InvalidShape { .. }));
//!
//! // A well-formed call: build, submit, wait on the ticket.
//! let ticket = client
//!     .call(urand(8, 8, -1.0, 1.0, 1), urand(8, 8, -1.0, 1.0, 2))
//!     .policy(Policy::Fp32Accuracy)
//!     .deadline(Duration::from_secs(30))
//!     .tag("doc-example")
//!     .submit()
//!     .expect("admitted");
//! let outcome = ticket.wait().expect("served within the deadline");
//! assert_eq!(outcome.tag.as_deref(), Some("doc-example"));
//! client.shutdown();
//! ```

pub mod builder;
pub mod client;
pub mod error;
pub mod ticket;

pub use builder::ServiceBuilder;
pub use client::{Client, GemmCall, Priority, Session};
pub use error::ServiceError;
pub use ticket::{CancelToken, GemmResult, Ticket};

// The success payload lives with the coordinator's wire types; re-export it
// so `api` is self-contained for clients.
pub use crate::coordinator::request::GemmOutcome;
