//! Bit-exactness rules. These encode the invariants that make the
//! corrected Tensor-Core GEMM bit-identical to its oracles: a single
//! sanctioned rounding site, fixed-order reductions, and no float
//! nondeterminism sneaking in through containers or fused ops.

use crate::diag::{Finding, RuleId};
use crate::lexer::FileModel;

/// Run the per-line bit-exactness rules over one in-scope file.
pub fn run(fm: &FileModel, out: &mut Vec<Finding>) {
    let in_fp = fm.path.contains("/fp/");
    for idx in 0..fm.line_count() {
        let line = idx + 1;
        if fm.is_test_line(line) {
            continue;
        }
        let code = fm.code(line);
        if contains_word(code, "HashMap") || contains_word(code, "HashSet") {
            push(out, fm, RuleId::HashContainer, line,
                "unordered container in a bit-exact module; iteration order feeds numerics — \
                 use BTreeMap/Vec or sort explicitly");
        }
        if has_f32_fold(code) || code.contains(".sum::<f32>") {
            push(out, fm, RuleId::FloatFold, line,
                "f32 accumulation via fold/sum; prove the reduction order fixed or \
                 order-independent, or rewrite as an indexed loop");
        }
        if code.contains(".mul_add(") {
            push(out, fm, RuleId::MulAdd, line,
                "mul_add fuses its rounding step and diverges from the modeled \
                 multiply-then-add hardware path");
        }
        if has_nonzero_float_cmp(code) {
            push(out, fm, RuleId::FloatCmp, line,
                "bare ==/!= against a non-zero float literal; compare via to_bits or an \
                 explicit tolerance");
        }
        if !in_fp && has_as_f32(code) {
            push(out, fm, RuleId::LossyCast, line,
                "`as f32` narrowing outside fp/ violates the single-rounding-site policy; \
                 route through fp::rounding");
        }
    }
}

fn push(out: &mut Vec<Finding>, fm: &FileModel, rule: RuleId, line: usize, msg: &str) {
    out.push(Finding {
        rule,
        path: fm.path.clone(),
        line,
        message: msg.to_string(),
        src_line: fm.raw(line).to_string(),
    });
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// `needle` present with non-identifier bytes on both sides.
fn contains_word(code: &str, needle: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(needle) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let end = at + needle.len();
        let after_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

/// `.fold(` whose first argument is an f32-suffixed zero literal
/// (`0.0f32`, `0f32`, `0.0_f32`, ...).
fn has_f32_fold(code: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(".fold(") {
        let rest = code[start + pos + ".fold(".len()..].trim_start();
        for form in ["0.0f32", "0f32", "0.0_f32", "0_f32"] {
            if rest.starts_with(form) {
                return true;
            }
        }
        start += pos + 1;
    }
    false
}

/// ` as f32` with a non-identifier byte after the `f32`.
fn has_as_f32(code: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(" as f32") {
        let end = start + pos + " as f32".len();
        if end >= code.len() || !is_ident(code.as_bytes()[end]) {
            return true;
        }
        start += pos + 1;
    }
    false
}

/// `==`/`!=` with a non-zero float literal on either side. Comparisons to
/// `0.0` are exact (no rounding can hide there) and deliberately allowed —
/// the tree uses them for zero-operand short-circuits.
fn has_nonzero_float_cmp(code: &str) -> bool {
    let bytes = code.as_bytes();
    for i in 0..bytes.len().saturating_sub(1) {
        let two = &bytes[i..i + 2];
        if two != b"==" && two != b"!=" {
            continue;
        }
        // Skip `<=`, `>=`, `=>`-adjacent and `===`-like shapes (not Rust,
        // but cheap to exclude).
        if i > 0 && matches!(bytes[i - 1], b'<' | b'>' | b'=' | b'!') {
            continue;
        }
        if bytes.get(i + 2) == Some(&b'=') {
            continue;
        }
        let left = token_before(code, i);
        let right = token_after(code, i + 2);
        if is_nonzero_float_literal(&left) || is_nonzero_float_literal(&right) {
            return true;
        }
    }
    false
}

fn token_before(code: &str, end: usize) -> String {
    let bytes = code.as_bytes();
    let mut s = end;
    while s > 0 && bytes[s - 1] == b' ' {
        s -= 1;
    }
    let stop = s;
    while s > 0 && (is_ident(bytes[s - 1]) || matches!(bytes[s - 1], b'.' | b'-')) {
        s -= 1;
    }
    code[s..stop].to_string()
}

fn token_after(code: &str, start: usize) -> String {
    let bytes = code.as_bytes();
    let mut s = start;
    while s < bytes.len() && bytes[s] == b' ' {
        s += 1;
    }
    let begin = s;
    if s < bytes.len() && bytes[s] == b'-' {
        s += 1;
    }
    while s < bytes.len() && (is_ident(bytes[s]) || bytes[s] == b'.') {
        s += 1;
    }
    code[begin..s].to_string()
}

/// A decimal float literal containing a dot (optional exponent,
/// `_`/`f32`/`f64` suffix, sign) with non-zero value.
fn is_nonzero_float_literal(tok: &str) -> bool {
    let t = tok.strip_prefix('-').unwrap_or(tok);
    let t = t.strip_suffix("f64").or_else(|| t.strip_suffix("f32")).unwrap_or(t);
    let t = t.strip_suffix('_').unwrap_or(t);
    if t.is_empty() || !t.as_bytes()[0].is_ascii_digit() || !t.contains('.') {
        return false;
    }
    if !t.bytes().all(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'-' | b'_')) {
        return false;
    }
    t.replace('_', "").parse::<f64>().map(|v| v != 0.0).unwrap_or(false)
}
