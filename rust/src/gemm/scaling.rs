//! Exponent pre-scaling — the paper's prescribed remedy for Fig. 11's
//! Type-3/4 inputs: "if all elements in the matrix have very small
//! exponents, we need to carry out additional scaling before matrix-matrix
//! multiplication is performed".
//!
//! `C = A·B = (A·2^sa)·(B·2^sb) / 2^(sa+sb)`: powers of two are exact in
//! binary floating point, so pre-scaling each operand so its largest
//! exponent lands at 0 moves the whole computation into halfhalf's sweet
//! spot without changing a single mantissa bit. The de-scale is folded into
//! the FP32 epilogue.

use super::matrix::Mat;
use super::tiled::TileConfig;
use super::Method;
use crate::fp::exp2i;
use crate::fp::mantissa::exponent_of;

/// The scaling decision for one operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScalePlan {
    /// Multiply the operand by `2^shift` before the GEMM.
    pub shift: i32,
}

/// Plan a shift that brings the operand's largest exponent to 0 (power of
/// two ⇒ mantissa-exact). Returns shift = 0 for all-zero input.
pub fn plan_scale(m: &Mat) -> ScalePlan {
    let mut max_e = i32::MIN;
    for &v in &m.data {
        if v != 0.0 && v.is_finite() {
            max_e = max_e.max(exponent_of(v));
        }
    }
    if max_e == i32::MIN {
        return ScalePlan { shift: 0 };
    }
    // Clamp so the scaled values stay comfortably inside f32 (and the
    // ×2^11 residual scaling keeps headroom).
    ScalePlan { shift: (-max_e).clamp(-120, 140) }
}

/// Apply a plan: exact elementwise ×2^shift.
pub fn apply_scale(m: &Mat, plan: ScalePlan) -> Mat {
    if plan.shift == 0 {
        return m.clone();
    }
    // Split huge shifts into two exact factors to avoid f64→f32 overflow
    // at intermediate steps.
    let (s1, s2) = if plan.shift > 127 {
        (127, plan.shift - 127)
    } else if plan.shift < -126 {
        (-126, plan.shift + 126)
    } else {
        (plan.shift, 0)
    };
    let f1 = exp2i(s1) as f32;
    let f2 = exp2i(s2) as f32;
    m.map(|x| x * f1 * f2)
}

/// Exact two-step descale epilogue: multiply every element by `2^total`,
/// split into two in-range power-of-two factors so huge shifts survive.
/// Shared by [`gemm_scaled`] and the shard engine's prescale hoist
/// (`shard::exec`), whose bit-identity guarantee requires both paths to
/// apply the *same* factor sequence add-for-add.
pub fn descale_pow2(c: &Mat, total: i32) -> Mat {
    let (s1, s2) = if total > 127 {
        (127, total - 127)
    } else if total < -126 {
        (-126, total + 126)
    } else {
        (total, 0)
    };
    let f1 = exp2i(s1) as f32;
    let f2 = exp2i(s2) as f32;
    c.map(|x| x * f1 * f2)
}

/// `C = A·B` with pre-scaling: scale both operands into range, run
/// `method`, descale the result in the FP32 epilogue.
///
/// The combined descale `2^-(sa+sb)` can undershoot f32 for extreme inputs
/// (e.g. both operands ~2^-90 ⇒ products ~2^-180, unrepresentable — the
/// *true* C underflows too); the epilogue applies the descale in two exact
/// steps so everything representable survives.
pub fn gemm_scaled(a: &Mat, b: &Mat, method: Method, cfg: &TileConfig) -> Mat {
    let pa = plan_scale(a);
    let pb = plan_scale(b);
    let a_s = apply_scale(a, pa);
    let b_s = apply_scale(b, pb);
    let c_s = method.run(&a_s, &b_s, cfg);
    descale_pow2(&c_s, -(pa.shift + pb.shift))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{gemm_f64, relative_residual};
    use crate::matgen::{exp_rand, urand};

    #[test]
    fn plan_centers_max_exponent() {
        let m = exp_rand(16, 16, -100, -36, 1);
        let p = plan_scale(&m);
        let scaled = apply_scale(&m, p);
        let max_e = scaled
            .data
            .iter()
            .filter(|v| **v != 0.0)
            .map(|&v| exponent_of(v))
            .max()
            .unwrap();
        assert_eq!(max_e, 0);
        assert_eq!(plan_scale(&Mat::zeros(4, 4)).shift, 0);
    }

    #[test]
    fn scaling_is_mantissa_exact() {
        let m = urand(8, 8, -1.0, 1.0, 2);
        let p = ScalePlan { shift: 37 };
        let s = apply_scale(&m, p);
        for (x, y) in m.data.iter().zip(s.data.iter()) {
            assert_eq!(x.to_bits() & 0x007f_ffff, y.to_bits() & 0x007f_ffff, "mantissa changed");
        }
    }

    #[test]
    fn type4_rescued_by_scaling() {
        // Fig. 11 Type 4: halfhalf alone is unusable (residual ~1);
        // with pre-scaling it matches FP32 SIMT.
        let cfg = TileConfig::default();
        let a = exp_rand(48, 48, -100, -36, 3);
        let b = exp_rand(48, 48, -100, -36, 4);
        let r = gemm_f64(&a, &b);
        let raw = relative_residual(&r, &Method::OursHalfHalf.run(&a, &b, &cfg));
        let scaled = relative_residual(&r, &gemm_scaled(&a, &b, Method::OursHalfHalf, &cfg));
        let simt = relative_residual(&r, &Method::Fp32Simt.run(&a, &b, &cfg));
        assert!(raw > 0.9, "raw halfhalf should fail: {raw}");
        assert!(scaled <= 2.5 * simt, "scaled {scaled} vs simt {simt}");
    }

    #[test]
    fn type2_mixed_ranges_also_rescued() {
        let cfg = TileConfig::default();
        let a = urand(32, 32, -1.0, 1.0, 5);
        let b = exp_rand(32, 32, -100, -36, 6);
        let r = gemm_f64(&a, &b);
        let scaled = relative_residual(&r, &gemm_scaled(&a, &b, Method::OursHalfHalf, &cfg));
        let simt = relative_residual(&r, &Method::Fp32Simt.run(&a, &b, &cfg));
        assert!(scaled <= 2.5 * simt, "scaled {scaled} vs simt {simt}");
    }

    #[test]
    fn in_range_inputs_unaffected_quality() {
        // Scaling an already-fine input must not hurt.
        let cfg = TileConfig::default();
        let a = urand(32, 32, -1.0, 1.0, 7);
        let b = urand(32, 32, -1.0, 1.0, 8);
        let r = gemm_f64(&a, &b);
        let plain = relative_residual(&r, &Method::OursHalfHalf.run(&a, &b, &cfg));
        let scaled = relative_residual(&r, &gemm_scaled(&a, &b, Method::OursHalfHalf, &cfg));
        assert!(scaled <= 2.0 * plain + 1e-12, "scaled {scaled} vs plain {plain}");
    }
}
