//! Accuracy metric — eq. (7):
//! `RelativeResidual = ||C_FP64 − C_Target||_F / ||C_FP64||_F`.

use super::matrix::{Mat, MatF64};

/// Relative Frobenius residual of `c` against the FP64 oracle `c_ref`.
pub fn relative_residual(c_ref: &MatF64, c: &Mat) -> f64 {
    assert_eq!(c_ref.rows, c.rows);
    assert_eq!(c_ref.cols, c.cols);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (r, t) in c_ref.data.iter().zip(c.data.iter()) {
        let d = r - *t as f64;
        num += d * d;
        den += r * r;
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Max elementwise relative error (supplementary diagnostic).
pub fn max_rel_error(c_ref: &MatF64, c: &Mat) -> f64 {
    c_ref
        .data
        .iter()
        .zip(c.data.iter())
        .map(|(r, t)| {
            if *r == 0.0 {
                if *t == 0.0 {
                    0.0
                } else {
                    f64::INFINITY
                }
            } else {
                ((r - *t as f64) / r).abs()
            }
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_residual_for_exact() {
        let r = MatF64 { rows: 1, cols: 2, data: vec![1.0, 2.0] };
        let c = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        assert_eq!(relative_residual(&r, &c), 0.0);
        assert_eq!(max_rel_error(&r, &c), 0.0);
    }

    #[test]
    fn known_residual() {
        let r = MatF64 { rows: 1, cols: 2, data: vec![3.0, 4.0] };
        let c = Mat::from_vec(1, 2, vec![3.0, 5.0]);
        // ||(0,-1)|| / ||(3,4)|| = 1/5
        assert!((relative_residual(&r, &c) - 0.2).abs() < 1e-15);
    }

    #[test]
    fn zero_reference() {
        let r = MatF64::zeros(2, 2);
        let c = Mat::zeros(2, 2);
        assert_eq!(relative_residual(&r, &c), 0.0);
        let c2 = Mat::from_vec(2, 2, vec![0.0, 1.0, 0.0, 0.0]);
        assert_eq!(relative_residual(&r, &c2), f64::INFINITY);
    }
}
