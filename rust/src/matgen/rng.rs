//! Deterministic, dependency-free PRNG (xoshiro256**) for workload
//! generation. Every experiment takes an explicit seed so paper figures are
//! exactly re-generable.

/// xoshiro256** by Blackman & Vigna (public domain reference constants).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn int_in_covers_bounds() {
        let mut r = Rng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.int_in(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
