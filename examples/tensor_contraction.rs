//! Quantum-circuit-style tensor-network contraction — the paper's second
//! motivating application (qFlex rejected FP16 Tensor Cores because of the
//! exponent range; TF32 + correction fixes exactly that).
//!
//! Uses the library's complex GEMM (`tcec::gemm::complex`, 3M algorithm —
//! the same trick cuBLAS CGEMM3M uses) to contract a chain of complex gate
//! layers whose magnitudes decay layer by layer: amplitudes in circuit
//! simulations shrink exponentially, pushing values toward the FP16 cliff.
//! Fidelity is tracked against an FP64 contraction.
//!
//! Expected: plain FP16-TC loses the state entirely; halfhalf degrades once
//! magnitudes fall below ~2^-15 (Fig. 11 Types 2-4); tf32tf32 and the bf16
//! triple-split track FP32 the whole way — "TF32 can represent nearly the
//! entire FP32 exponent range".
//!
//! Run: `cargo run --release --example tensor_contraction`

use tcec::gemm::{
    c_relative_residual, cgemm, cgemm_f64, CgemmAlgo, CMat, Mat, Method, TileConfig,
};
use tcec::matgen::Rng;

/// Random "gate layer" with magnitude scale s (unitary-ish, not exactly).
fn layer(n: usize, s: f64, seed: u64) -> CMat {
    let mut rng = Rng::new(seed);
    let norm = s / (n as f64).sqrt();
    CMat {
        re: Mat::from_fn(n, n, |_, _| (rng.normal() * norm) as f32),
        im: Mat::from_fn(n, n, |_, _| (rng.normal() * norm) as f32),
    }
}

fn main() {
    let n = 48;
    let layers = 10;
    // Each layer shrinks amplitudes ~8x: after 10 layers values sit around
    // 2^-30 of the start — exactly the regime qFlex worried about.
    let shrink = 0.125;
    let cfg = TileConfig::default();
    let methods = [
        Method::Fp16Tc,
        Method::OursHalfHalf,
        Method::OursTf32,
        Method::OursBf16Triple,
        Method::Fp32Simt,
    ];

    println!(
        "contracting {layers} complex {n}x{n} gate layers (3M CGEMM), shrink {shrink}/layer\n"
    );
    println!(
        "{:>5} {:>10} {:>13} {:>13} {:>13} {:>13} {:>13}",
        "layer", "|amp|~2^e", "fp16tc", "halfhalf", "tf32tf32", "bf16x3", "fp32_simt"
    );

    let init = layer(n, 1.0, 7000);
    let mut states: Vec<CMat> = methods.iter().map(|_| init.clone()).collect();
    // FP64 reference state, carried as an exact CMat re-derived per layer.
    let mut exact_state = init.clone();
    let mut exact_ref = cgemm_f64(
        &exact_state,
        &CMat {
            re: Mat::from_fn(n, n, |i, j| (i == j) as u32 as f32),
            im: Mat::zeros(n, n),
        },
    );

    let mut final_errs = vec![0.0f64; methods.len()];
    for l in 0..layers {
        let g = layer(n, shrink, 8000 + l as u64);
        // Reference: contract in FP64, then round the state to f32 for the
        // next exact step (the f32 state is what the methods start from,
        // so the comparison isolates GEMM error per chain).
        exact_ref = cgemm_f64(&exact_state, &g);
        exact_state = CMat {
            re: Mat::from_vec(n, n, exact_ref.re.data.iter().map(|&v| v as f32).collect()),
            im: Mat::from_vec(n, n, exact_ref.im.data.iter().map(|&v| v as f32).collect()),
        };
        let mag = exact_ref
            .re
            .data
            .iter()
            .zip(&exact_ref.im.data)
            .map(|(r, i)| (r * r + i * i).sqrt())
            .fold(0.0, f64::max);
        print!("{:>5} {:>10}", l + 1, format!("2^{:.0}", mag.log2()));
        for (mi, &m) in methods.iter().enumerate() {
            states[mi] = cgemm(&states[mi], &g, m, CgemmAlgo::ThreeM, &cfg);
            let e = c_relative_residual(&exact_ref, &states[mi]);
            final_errs[mi] = e;
            print!(" {:>13.3e}", e);
        }
        println!();
    }

    let idx = |m: Method| methods.iter().position(|&x| x == m).unwrap();
    let tf32 = final_errs[idx(Method::OursTf32)];
    let bf16 = final_errs[idx(Method::OursBf16Triple)];
    let simt = final_errs[idx(Method::Fp32Simt)];
    let f16 = final_errs[idx(Method::Fp16Tc)];
    println!(
        "\nfinal fidelity error: fp16tc {f16:.3e}, tf32tf32 {tf32:.3e}, bf16x3 {bf16:.3e}, fp32 {simt:.3e}"
    );
    assert!(tf32 < 10.0 * simt, "tf32tf32 must track FP32 through the exponent decay");
    assert!(bf16 < 10.0 * simt, "bf16x3 must track FP32 through the exponent decay");
    assert!(f16 > 100.0 * tf32, "plain FP16-TC must have lost the state by now");
    println!("OK: wide-exponent corrected kernels survive the amplitude decay that kills FP16.");
}
