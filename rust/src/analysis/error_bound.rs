//! Probabilistic error-growth model — the quantitative version of the
//! paper's Fig. 1/Fig. 5 argument (cf. Blanchard et al., "Mixed Precision
//! Block FMA: Error Analysis", which the paper builds on).
//!
//! For a length-`k` inner product of O(1) i.i.d. terms:
//! * **RN accumulation** (FP32 SIMT, or the paper's fixed kernel): rounding
//!   errors are zero-mean ⇒ they random-walk, residual ≈ c·√k·u with
//!   u = 2^-24.
//! * **RZ accumulation** (inside the Tensor Core): every rounding is biased
//!   toward zero ⇒ errors accumulate *coherently*, residual ≈ c'·k·u_acc
//!   with u_acc = 2^-25 (the 25-bit accumulator).
//!
//! The crossover explains Fig. 1 exactly: Markidis' corrected mantissa is
//! fine, but its linear RZ term overtakes the √k RN floor as k grows. The
//! tests fit the growth exponent of the measured residuals and check RN
//! paths sit near 0.5 and RZ paths near 1.0.

/// FP32 unit roundoff.
pub const U_FP32: f64 = 1.0 / (1u64 << 24) as f64;
/// Tensor-Core accumulator unit roundoff (25-bit significand).
pub const U_TC_ACC: f64 = 1.0 / (1u64 << 25) as f64;
/// FP64 unit roundoff.
pub const U_FP64: f64 = 1.0 / (1u64 << 53) as f64;

/// Predicted relative residual of an RN-accumulated FP32 inner product of
/// length k over urand(-1,1) data. The constant is the standard
/// random-walk factor for uniform data (≈ 0.5/√3 per step, empirically
/// ≈ 0.4 end to end).
pub fn predicted_rn(k: usize) -> f64 {
    0.4 * (k as f64).sqrt() * U_FP32
}

/// Predicted relative residual of an RZ-accumulated Tensor-Core chain:
/// each add truncates toward zero, losing u_acc/2 in expectation, and the
/// losses share a sign.
pub fn predicted_rz(k: usize) -> f64 {
    0.5 * k as f64 * U_TC_ACC
}

/// Least-squares slope of log(residual) vs log(k) — the growth exponent
/// (0.5 = random walk, 1.0 = coherent accumulation).
pub fn fit_growth_exponent(ks: &[usize], residuals: &[f64]) -> f64 {
    assert_eq!(ks.len(), residuals.len());
    assert!(ks.len() >= 2);
    let xs: Vec<f64> = ks.iter().map(|&k| (k as f64).ln()).collect();
    let ys: Vec<f64> = residuals.iter().map(|&r| r.max(1e-300).ln()).collect();
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

/// Provable worst-case bound on the normalized elementwise error of an
/// `s`-slice Ozaki GEMM with inner dimension `k`:
/// `max_ij |C - C̃| / (k · max|A| · max|B|) ≤ 16 · (s+1) · 2^(-β(k)·s)`.
///
/// Derivation: the dropped `p+q ≥ s` tail is the only error source — the
/// slice-pair products are exact in the 25-bit TC accumulator by the β
/// choice (`gemm::slice_bits`) and the terms are summed double-double.
/// Each dropped diagonal `p+q = d ≥ s` contributes at most
/// `k · σ_A σ_B · 2^(-β(d+1)) · (1 - 2^-β)^-2` per element; summing the
/// geometric tail over `d ≥ s` and bounding `σ ≤ 2·max|·|` per operand
/// (factor 4) and `(1-2^-β)^-2 ≤ 4` gives the stated form (the `s+1`
/// absorbs the diagonal multiplicities). One caveat rides on top at the
/// `2β + ⌈log₂ k⌉ = 25` boundary: the TC's final 24-bit RZ writeback can
/// truncate one slice-grid granule on sign-aligned adversarial data,
/// worth at most `8 · 2^(-2β) / k` normalized — inside the fp32 class
/// tolerance everywhere, and ~16σ away from random data (see
/// DESIGN.md §16).
pub fn ozaki_bound(k: usize, s: usize) -> f64 {
    let beta = crate::gemm::slice_bits(k) as i32;
    16.0 * (s as f64 + 1.0) * 2.0f64.powi(-(beta * s as i32))
}

/// Normalized tolerance of the **fp32 accuracy class**: the established
/// f32-method envelope [`predicted_rz`] (every f32-path method in the
/// evaluation sits at or below coherent RZ accumulation). An Ozaki plan is
/// fp32-admissible when [`ozaki_bound`] clears this.
pub fn fp32_class_tol(k: usize) -> f64 {
    predicted_rz(k)
}

/// Normalized tolerance of the **fp64 accuracy class**: coherent f64
/// rounding over a length-k chain, `0.5 · k · u64` — what a well-ordered
/// native FP64 GEMM guarantees.
pub fn fp64_class_tol(k: usize) -> f64 {
    0.5 * k as f64 * U_FP64
}

/// Predicted k at which an RZ-accumulated corrected method crosses above
/// the RN (FP32) floor — i.e. where Markidis stops being "accurate enough".
pub fn rz_rn_crossover_k() -> f64 {
    // 0.5 k u_acc = 0.4 sqrt(k) u  =>  sqrt(k) = 0.8 u / u_acc  => tiny:
    // the RZ term dominates almost immediately; the interesting quantity
    // is the RATIO at a given k.
    let r = 0.8 * U_FP32 / U_TC_ACC;
    r * r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::mean_residual;
    use crate::gemm::{Method, TileConfig};
    use crate::matgen::Workload;

    fn residual_series(method: Method, ks: &[usize]) -> Vec<f64> {
        let w = Workload::Urand { lo: -1.0, hi: 1.0 };
        let cfg = TileConfig::default();
        ks.iter().map(|&k| mean_residual(method, w, w, 16, 16, k, 4, &cfg)).collect()
    }

    #[test]
    fn simt_grows_like_sqrt_k() {
        let ks = [256, 512, 1024, 2048, 4096];
        let rs = residual_series(Method::Fp32Simt, &ks);
        let slope = fit_growth_exponent(&ks, &rs);
        assert!((0.3..0.75).contains(&slope), "RN slope {slope} (expected ~0.5)");
    }

    #[test]
    fn markidis_grows_like_k() {
        let ks = [256, 512, 1024, 2048, 4096];
        let rs = residual_series(Method::Markidis, &ks);
        let slope = fit_growth_exponent(&ks, &rs);
        assert!((0.8..1.2).contains(&slope), "RZ slope {slope} (expected ~1.0)");
    }

    #[test]
    fn ours_inherits_the_rn_exponent() {
        // The whole point of the RZ-avoidance: the corrected kernel's
        // growth exponent matches the SIMT one, not Markidis'.
        let ks = [256, 512, 1024, 2048, 4096];
        let rs = residual_series(Method::OursHalfHalf, &ks);
        let slope = fit_growth_exponent(&ks, &rs);
        assert!(slope < 0.8, "ours slope {slope} (must stay sub-linear)");
    }

    #[test]
    fn predictions_within_order_of_magnitude() {
        let w = Workload::Urand { lo: -1.0, hi: 1.0 };
        let cfg = TileConfig::default();
        for k in [512usize, 2048] {
            let simt = mean_residual(Method::Fp32Simt, w, w, 16, 16, k, 4, &cfg);
            let markidis = mean_residual(Method::Markidis, w, w, 16, 16, k, 4, &cfg);
            let p_rn = predicted_rn(k);
            let p_rz = predicted_rz(k);
            assert!(simt / p_rn < 5.0 && p_rn / simt < 5.0, "k={k} simt {simt} vs {p_rn}");
            assert!(
                markidis / p_rz < 5.0 && p_rz / markidis < 5.0,
                "k={k} markidis {markidis} vs {p_rz}"
            );
        }
    }

    #[test]
    fn ozaki_bound_gates_both_accuracy_classes() {
        use crate::gemm::{slice_bits, slices_for_fp32, slices_for_fp64};
        // Headline pins at k=512 (β=8 after the ceil_log2 fix):
        // fp32 class needs exactly 3 slices, fp64 exactly 7.
        assert!(ozaki_bound(512, 3) <= fp32_class_tol(512));
        assert!(ozaki_bound(512, 2) > fp32_class_tol(512));
        assert!(ozaki_bound(512, 7) <= fp64_class_tol(512));
        assert!(ozaki_bound(512, 6) > fp64_class_tol(512));
        // The coverage-based slice counts are bound-admissible at every
        // power of two, and the bound is strictly decreasing in s.
        let mut k = 1usize;
        while k <= 16384 {
            let beta = slice_bits(k);
            assert!(
                ozaki_bound(k, slices_for_fp32(beta)) <= fp32_class_tol(k),
                "k={k}: fp32 coverage slices not admissible"
            );
            assert!(
                ozaki_bound(k, slices_for_fp64(beta)) <= fp64_class_tol(k),
                "k={k}: fp64 coverage slices not admissible"
            );
            for s in 1..12 {
                assert!(ozaki_bound(k, s + 1) < ozaki_bound(k, s), "k={k} s={s}");
            }
            k *= 2;
        }
    }

    #[test]
    fn fit_recovers_known_slopes() {
        let ks = [16usize, 64, 256, 1024];
        let lin: Vec<f64> = ks.iter().map(|&k| 3.0 * k as f64).collect();
        let sqrt: Vec<f64> = ks.iter().map(|&k| 3.0 * (k as f64).sqrt()).collect();
        assert!((fit_growth_exponent(&ks, &lin) - 1.0).abs() < 1e-9);
        assert!((fit_growth_exponent(&ks, &sqrt) - 0.5).abs() < 1e-9);
    }
}
