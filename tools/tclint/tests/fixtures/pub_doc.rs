// tclint-fixture-path: rust/src/api/fx_doc.rs
pub fn naked() -> u32 {
    7
}

/// Documented.
pub fn covered() -> u32 {
    9
}

/// Documented through an attribute stack.
#[derive(Debug)]
pub struct Wrapped;

pub mod plumbing {}
