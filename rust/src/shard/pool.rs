//! Work-stealing shard worker pool.
//!
//! Replaces the coordinator's one-batch-per-worker handoff for large
//! requests: shards of a large GEMM are distributed round-robin across
//! per-worker deques, each worker drains its own deque from the front, and
//! an idle worker *steals* from the back of the longest other deque. Large
//! ragged shards (edge tiles, uneven k-slices) therefore cannot serialize
//! the pool behind one slow worker — the classic Cilk/Chase–Lev argument,
//! here with a single pool mutex instead of lock-free deques (shard grains
//! are milliseconds of simulated GEMM, so queue-op contention is noise;
//! DESIGN.md §Sharded-execution).
//!
//! Jobs are opaque closures; panics are caught per job (a poisoned shard
//! must not take the pool down — mirrors the service worker's policy), and
//! the submitting side observes the failure as a dropped result channel.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A pool job. The `bool` argument tells the job whether it was *stolen*
/// (executed by a worker other than the one it was queued on) — submitters
/// use it for exact per-request steal attribution.
type Job = Box<dyn FnOnce(bool) + Send + 'static>;

struct PoolState {
    queues: Vec<VecDeque<Job>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    available: Condvar,
    /// Per-worker count of jobs taken from *another* worker's deque.
    steals: Vec<AtomicU64>,
    /// Per-worker count of jobs executed (own + stolen), counted at
    /// dequeue — before the job body runs, so anything the job publishes
    /// (channel sends) happens-after the increment.
    executed: Vec<AtomicU64>,
}

/// Fixed-size work-stealing pool executing boxed shard jobs.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    next: AtomicUsize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                queues: (0..workers).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            available: Condvar::new(),
            steals: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            executed: (0..workers).map(|_| AtomicU64::new(0)).collect(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("shard-worker-{i}"))
                    .spawn(move || worker_main(i, shared))
                    // tclint: allow(hot-unwrap) -- construction-time spawn, before any request is admitted; failing to build the pool should abort startup
                    .expect("spawn shard worker")
            })
            .collect();
        WorkerPool { shared, handles, next: AtomicUsize::new(0) }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue a job on the next deque round-robin. Consecutive submissions
    /// of one request's shards spread across all workers, so stealing only
    /// kicks in for imbalance, not for initial distribution.
    pub fn submit(&self, job: Job) {
        let w = self.next.fetch_add(1, Ordering::Relaxed) % self.workers();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.queues[w].push_back(job);
        }
        // One job → one wakeup; any woken worker can claim it via the
        // steal path. (Shutdown uses notify_all in Drop.)
        self.shared.available.notify_one();
    }

    /// Total steals across all workers since pool start.
    pub fn steal_count(&self) -> u64 {
        self.shared.steals.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Total jobs executed across all workers since pool start.
    pub fn executed_count(&self) -> u64 {
        self.shared.executed.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(me: usize, shared: Arc<PoolShared>) {
    loop {
        let mut more_work = false;
        let job: Option<(Job, bool)> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.queues[me].pop_front() {
                    more_work = st.queues.iter().any(|q| !q.is_empty());
                    break Some((j, false));
                }
                // Steal from the back of the longest non-empty deque.
                let victim = (0..st.queues.len())
                    .filter(|&v| v != me && !st.queues[v].is_empty())
                    .max_by_key(|&v| st.queues[v].len());
                if let Some(v) = victim {
                    if let Some(j) = st.queues[v].pop_back() {
                        shared.steals[me].fetch_add(1, Ordering::Relaxed);
                        more_work = st.queues.iter().any(|q| !q.is_empty());
                        break Some((j, true));
                    }
                }
                if st.shutdown {
                    break None;
                }
                st = shared.available.wait(st).unwrap();
            }
        };
        // Chained wakeup: a `notify_one` from `submit` may land on a worker
        // that is already awake; re-notify while work remains so sleeping
        // siblings get pulled in before this job's (long) execution.
        if more_work {
            shared.available.notify_one();
        }
        match job {
            Some((j, stolen)) => {
                // Count first: observers unblocked by the job's own sends
                // must already see the increment. Shard jobs report failure
                // by dropping their result sender; a panic must not kill
                // the worker.
                shared.executed[me].fetch_add(1, Ordering::Relaxed);
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || j(stolen)));
            }
            None => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    #[test]
    fn executes_all_jobs() {
        let pool = WorkerPool::new(3);
        let (tx, rx) = channel();
        for i in 0..50u64 {
            let tx = tx.clone();
            pool.submit(Box::new(move |_| {
                let _ = tx.send(i);
            }));
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
        assert_eq!(pool.executed_count(), 50);
    }

    #[test]
    fn stealing_rebalances_a_skewed_load() {
        // Worker 0 gets one long job; the short jobs queued behind it on
        // the same deque must be stolen and finish long before it does.
        let pool = WorkerPool::new(2);
        let (tx, rx) = channel();
        // Round-robin: even submissions land on worker 0.
        let slow_tx = tx.clone();
        pool.submit(Box::new(move |_| {
            std::thread::sleep(Duration::from_millis(300));
            let _ = slow_tx.send("slow");
        }));
        let fast_tx = tx.clone();
        pool.submit(Box::new(move |_| {
            let _ = fast_tx.send("fast1");
        }));
        // Lands behind the slow job on worker 0's deque.
        let stuck_tx = tx.clone();
        pool.submit(Box::new(move |_| {
            let _ = stuck_tx.send("fast2");
        }));
        drop(tx);
        let first = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        let second = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_ne!(first, "slow", "fast jobs must not wait behind the slow one");
        assert_ne!(second, "slow");
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)).unwrap(), "slow");
        assert!(pool.steal_count() >= 1, "expected at least one steal");
    }

    #[test]
    fn panicking_job_does_not_kill_workers() {
        let pool = WorkerPool::new(1);
        let (tx, rx) = channel();
        pool.submit(Box::new(|_| panic!("injected shard failure")));
        pool.submit(Box::new(move |_| {
            let _ = tx.send(());
        }));
        assert!(rx.recv_timeout(Duration::from_secs(10)).is_ok());
        assert_eq!(pool.executed_count(), 2);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.workers(), 4);
        drop(pool); // must not hang
    }
}
