//! §Perf client-API overhead bench: what the versioned surface
//! (`call → submit → Ticket → wait`, DESIGN.md §10) costs over invoking
//! the executor directly in-process (no intake, no batcher, no worker
//! hop), at n = 64 and 256, with and without background contention. The
//! service adds admission control (one mutex+condvar hop), dispatch,
//! batching and a reply channel per request — this table keeps that
//! overhead honest (it should stay well under the GEMM itself at every
//! size).
//!
//! Run: `cargo bench --bench api_overhead` (`-- --smoke` for the CI smoke
//! lane).
//!
//! Note on the contended mode: `Metrics` tallies are plain relaxed
//! `AtomicU64`s (a mutex guards only the composite per-method map and
//! registered handles), so the background-traffic thread no longer
//! serializes with the measured rounds on a metrics lock — the contended
//! delta here reflects intake/batcher interleaving, not counter updates.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use tcec::bench_util::{bench, bench_params, smoke, Table};
use tcec::coordinator::{BatchKey, Executor, GemmRequest, GemmService, Policy, SimExecutor};
use tcec::gemm::Method;
use tcec::matgen::urand;

/// Requests per measured batch (amortizes clock overhead).
const REQS: usize = 16;

fn service() -> GemmService {
    // Fp32Simt forced: the cheapest backend, so the API path is the
    // largest possible fraction of the measured time.
    GemmService::builder()
        .workers(2)
        .max_batch(8)
        .queue_cap(4096)
        .force_method(Method::Fp32Simt)
        .build(Arc::new(SimExecutor::new()))
}

/// One measured round on the versioned API: REQS submits, then wait all.
fn round_api(svc: &GemmService, n: usize, seed: u64) {
    let tickets: Vec<_> = (0..REQS as u64)
        .map(|i| {
            svc.call(urand(n, n, -1.0, 1.0, seed + i), urand(n, n, -1.0, 1.0, seed + i + 500))
                .policy(Policy::StrictFp32)
                .submit()
                .expect("admitted")
        })
        .collect();
    for t in tickets {
        t.wait().expect("served");
    }
}

/// The floor: the same REQS GEMMs executed directly on the executor, no
/// service in between.
fn round_direct(exec: &SimExecutor, n: usize, seed: u64) {
    let key = BatchKey { m: n, n, k: n, method: Method::Fp32Simt };
    for i in 0..REQS as u64 {
        let reqs = [GemmRequest {
            id: i,
            a: urand(n, n, -1.0, 1.0, seed + i),
            b: urand(n, n, -1.0, 1.0, seed + i + 500),
            policy: Policy::StrictFp32,
        }];
        std::hint::black_box(exec.execute(&key, &reqs));
    }
}

fn measure(contended: bool, sizes: &[usize]) -> Vec<[String; 4]> {
    let (wu, mi, mt) = bench_params(1, 3, 0.3);
    let mut rows = Vec::new();
    for &n in sizes {
        let exec = SimExecutor::new();
        let svc = service();
        // Contended mode: a background thread keeps a steady stream of
        // same-shape traffic flowing while the measured rounds run, so
        // the intake lock and the batcher see realistic interleaving.
        let (s_api, s_direct) = if contended {
            let stop = AtomicBool::new(false);
            std::thread::scope(|scope| {
                let svc_ref = &svc;
                let stop_ref = &stop;
                scope.spawn(move || {
                    let mut i = 0u64;
                    while !stop_ref.load(Ordering::Relaxed) {
                        let _ = svc_ref
                            .call(urand(n, n, -1.0, 1.0, i), urand(n, n, -1.0, 1.0, i + 9000))
                            .policy(Policy::StrictFp32)
                            .wait();
                        i += 1;
                    }
                });
                let a = bench(|| round_api(&svc, n, 1), wu, mi, mt);
                let d = bench(|| round_direct(&exec, n, 2), wu, mi, mt);
                stop.store(true, Ordering::Relaxed);
                (a, d)
            })
        } else {
            let a = bench(|| round_api(&svc, n, 1), wu, mi, mt);
            let d = bench(|| round_direct(&exec, n, 2), wu, mi, mt);
            (a, d)
        };
        svc.shutdown();
        let per_req_api = s_api.median_s / REQS as f64 * 1e6;
        let per_req_direct = s_direct.median_s / REQS as f64 * 1e6;
        rows.push([
            n.to_string(),
            format!("{per_req_direct:.1}"),
            format!("{per_req_api:.1}"),
            format!("{:+.1}%", (per_req_api / per_req_direct - 1.0) * 100.0),
        ]);
    }
    rows
}

fn main() {
    let sizes: &[usize] = if smoke() { &[16] } else { &[64, 256] };
    println!("== client-API overhead: ticket path vs direct executor call ==");
    println!("   ({REQS} requests per round, Fp32Simt forced, 2 workers)\n");
    for contended in [false, true] {
        println!("-- {} --\n", if contended { "with background contention" } else { "idle" });
        let mut t = Table::new(&["n", "direct us/req", "ticket us/req", "delta"]);
        for row in measure(contended, sizes) {
            t.row(&row);
        }
        t.print();
        println!();
    }
}
