//! The Tensor-Core MMA emulator.
//!
//! Models `D = A×B + C` exactly the way the paper's own `mma_rn` / `mma_rz`
//! emulation does (§"Avoiding RZ during Tensor Core accumulation"):
//!
//! * element products are computed in *full* precision — an f16×f16 (or
//!   tf32×tf32) product has ≤22 significand bits and is exact in f64;
//! * the accumulator keeps `acc_precision` significand bits (default 25:
//!   FP32's 24 plus at least one extra carry bit, per Fasi et al. [6]) and
//!   is re-rounded with `acc_rounding` after **every** fused addition;
//! * the result is finally rounded to FP32.
//!
//! Real NVIDIA Tensor Cores use RZ in the accumulator; FP32 SIMT cores use
//! RN. Comparing the two configurations is the paper's Fig. 5 experiment and
//! the justification for accumulating `A16·B16` *outside* the Tensor Core.

use crate::fp::rounding::{round_to_precision, Rounding};
use std::cell::Cell;

/// Accumulator behaviour of a (simulated) Tensor Core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MmaConfig {
    /// Significand bits kept by the internal accumulator (incl. implicit).
    pub acc_precision: u32,
    /// Rounding applied after every fused add (and on the final FP32 store).
    pub acc_rounding: Rounding,
}

impl MmaConfig {
    /// Hardware Tensor Core: 25-bit accumulator, round-toward-zero.
    pub const TENSOR_CORE: MmaConfig =
        MmaConfig { acc_precision: 25, acc_rounding: Rounding::RZ };
    /// The paper's `mma_rn` reference device: same width, round-to-nearest.
    pub const MMA_RN: MmaConfig = MmaConfig { acc_precision: 25, acc_rounding: Rounding::RN };
    /// The paper's `mma_rz` reference device (equals TENSOR_CORE).
    pub const MMA_RZ: MmaConfig = MmaConfig { acc_precision: 25, acc_rounding: Rounding::RZ };
}

thread_local! {
    /// Count of scalar fused multiply-adds executed on the simulated Tensor
    /// Core (2 flops each). Drives flop accounting in benches/perfmodel.
    static MMA_FMA_COUNT: Cell<u64> = const { Cell::new(0) };
}

/// Reset the per-thread simulated-TC flop counter.
pub fn reset_fma_count() {
    MMA_FMA_COUNT.with(|c| c.set(0));
}

/// Read the per-thread simulated-TC flop counter (in FMAs).
pub fn fma_count() -> u64 {
    MMA_FMA_COUNT.with(|c| c.get())
}

/// Telemetry: attribute `steps` accumulator rounding steps to the RZ or
/// RN counter family (Fig. 5 — the rounding mode, not the width, is what
/// separates hardware Tensor Cores from the paper's `mma_rn` device).
/// One gated call per tile, never per element, so the simulator hot loop
/// is untouched. No-op when telemetry is disabled.
#[inline]
fn record_rounding_steps(mode: Rounding, steps: u64) {
    use crate::telemetry::numeric::{record, Counter};
    let c = if mode == Rounding::RZ { Counter::MmaStepsRz } else { Counter::MmaStepsRn };
    record(c, steps);
}

/// `d = a×b + c` over row-major tiles: `a` is m×k, `b` is k×n, `c`/`d` m×n.
///
/// `a` and `b` must already hold values on the input grid (f16 or TF32
/// values stored exactly in f32); the emulator does not re-round inputs.
/// The accumulation order is row-major over k, matching the paper's
/// sequential emulation.
pub fn mma_tile(
    d: &mut [f32],
    a: &[f32],
    b: &[f32],
    c: &[f32],
    m: usize,
    n: usize,
    k: usize,
    cfg: MmaConfig,
) {
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(d.len(), m * n);
    d.copy_from_slice(c);
    mma_tile_acc(d, a, b, m, n, k, cfg);
}

/// In-place variant: `d = a×b + d` (the fragment-accumulator pattern of
/// Code 2/3 without cloning the C tile). This is the simulator's hot loop:
/// the inner k-walk strides `b` by `n` so the (i, j) element's chain is
/// sequential, exactly like the paper's emulation.
pub fn mma_tile_acc(
    d: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    cfg: MmaConfig,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(d.len(), m * n);
    let p = cfg.acc_precision;
    let mode = cfg.acc_rounding;
    if mode == Rounding::RZ && (2..=52).contains(&p) {
        // Hardware Tensor-Core config: RZ truncation is a single bit-mask
        // (sign-magnitude ⇒ clearing low significand bits always moves
        // toward zero). §Perf iteration 5. Exactness vs the generic path is
        // covered by `rz_fast_path_matches_generic`.
        return mma_tile_acc_rz(d, a, b, m, n, k, p);
    }
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let d_row = &mut d[i * n..(i + 1) * n];
        // Each output element's accumulator chain is strictly serial
        // (rounding after every add), so walk several columns at once to
        // give the core independent latency chains (§Perf iterations 2/4).
        let mut j = 0;
        while j + 3 < n {
            let mut acc0 = d_row[j] as f64;
            let mut acc1 = d_row[j + 1] as f64;
            let mut acc2 = d_row[j + 2] as f64;
            let mut acc3 = d_row[j + 3] as f64;
            for (l, &av) in a_row.iter().enumerate() {
                let av = av as f64;
                let brow = l * n + j;
                acc0 = round_to_precision(acc0 + av * b[brow] as f64, p, mode);
                acc1 = round_to_precision(acc1 + av * b[brow + 1] as f64, p, mode);
                acc2 = round_to_precision(acc2 + av * b[brow + 2] as f64, p, mode);
                acc3 = round_to_precision(acc3 + av * b[brow + 3] as f64, p, mode);
            }
            d_row[j] = round_to_precision(acc0, 24, mode) as f32;
            d_row[j + 1] = round_to_precision(acc1, 24, mode) as f32;
            d_row[j + 2] = round_to_precision(acc2, 24, mode) as f32;
            d_row[j + 3] = round_to_precision(acc3, 24, mode) as f32;
            j += 4;
        }
        while j + 1 < n {
            let mut acc0 = d_row[j] as f64;
            let mut acc1 = d_row[j + 1] as f64;
            for (l, &av) in a_row.iter().enumerate() {
                let av = av as f64;
                let brow = l * n + j;
                acc0 = round_to_precision(acc0 + av * b[brow] as f64, p, mode);
                acc1 = round_to_precision(acc1 + av * b[brow + 1] as f64, p, mode);
            }
            // Final write-back to FP32 uses the same rounding as the
            // accumulator datapath.
            d_row[j] = round_to_precision(acc0, 24, mode) as f32;
            d_row[j + 1] = round_to_precision(acc1, 24, mode) as f32;
            j += 2;
        }
        if j < n {
            let mut acc = d_row[j] as f64;
            for (l, &av) in a_row.iter().enumerate() {
                acc = round_to_precision(acc + av as f64 * b[l * n + j] as f64, p, mode);
            }
            d_row[j] = round_to_precision(acc, 24, mode) as f32;
        }
    }
    MMA_FMA_COUNT.with(|cnt| cnt.set(cnt.get() + (m * n * k) as u64));
    record_rounding_steps(mode, (m * n * k) as u64);
}

/// RZ-specialized inner loop (see [`mma_tile_acc`] §Perf iteration 5).
fn mma_tile_acc_rz(d: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize, p: u32) {
    let acc_mask = !((1u64 << (53 - p)) - 1);
    let out_mask = !((1u64 << (53 - 24)) - 1);
    #[inline(always)]
    fn rz(x: f64, mask: u64) -> f64 {
        f64::from_bits(x.to_bits() & mask)
    }
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let d_row = &mut d[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 3 < n {
            let mut acc0 = d_row[j] as f64;
            let mut acc1 = d_row[j + 1] as f64;
            let mut acc2 = d_row[j + 2] as f64;
            let mut acc3 = d_row[j + 3] as f64;
            for (l, &av) in a_row.iter().enumerate() {
                let av = av as f64;
                let brow = l * n + j;
                acc0 = rz(acc0 + av * b[brow] as f64, acc_mask);
                acc1 = rz(acc1 + av * b[brow + 1] as f64, acc_mask);
                acc2 = rz(acc2 + av * b[brow + 2] as f64, acc_mask);
                acc3 = rz(acc3 + av * b[brow + 3] as f64, acc_mask);
            }
            d_row[j] = rz(acc0, out_mask) as f32;
            d_row[j + 1] = rz(acc1, out_mask) as f32;
            d_row[j + 2] = rz(acc2, out_mask) as f32;
            d_row[j + 3] = rz(acc3, out_mask) as f32;
            j += 4;
        }
        while j < n {
            let mut acc = d_row[j] as f64;
            for (l, &av) in a_row.iter().enumerate() {
                acc = rz(acc + av as f64 * b[l * n + j] as f64, acc_mask);
            }
            d_row[j] = rz(acc, out_mask) as f32;
            j += 1;
        }
    }
    MMA_FMA_COUNT.with(|cnt| cnt.set(cnt.get() + (m * n * k) as u64));
    record_rounding_steps(Rounding::RZ, (m * n * k) as u64);
}

/// `d = a×b` with an implicit zero C fragment (the RZ-avoidance pattern) —
/// overwrites `d` without any temporary allocation.
pub fn mma_tile_zero_into(
    d: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    cfg: MmaConfig,
) {
    d.iter_mut().for_each(|x| *x = 0.0);
    mma_tile_acc(d, a, b, m, n, k, cfg);
}

/// Instruction-chunked accumulate over a **chunk-major** packed A panel:
/// `d += A×B` issued as one [`mma_tile_acc`] call per `inst_k`-wide chunk,
/// exactly the per-chunk call sequence of the reference backends.
///
/// `a_cm` holds the m×kb A panel chunk-major: the chunk starting at
/// column `k0` occupies `a_cm[k0*m .. k0*m + m*kc]` as a packed m×kc
/// row-major block (`kc = min(inst_k, kb - k0)`). `b` is the kb×n panel
/// row-major, so each chunk's B view is the contiguous slice the
/// reference uses. Same slices, same `mma_tile_acc` calls in the same
/// order ⇒ bit-identical results and identical FMA/rounding-step counter
/// totals; the production engine packs A into this layout **once** per
/// k-block and shares it across every product term (DESIGN.md §14),
/// where the reference repacks per term per chunk.
#[allow(clippy::too_many_arguments)]
pub fn mma_tile_acc_chunked(
    d: &mut [f32],
    a_cm: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    kb: usize,
    inst_k: usize,
    cfg: MmaConfig,
) {
    debug_assert_eq!(a_cm.len(), m * kb);
    debug_assert_eq!(b.len(), kb * n);
    let mut k0 = 0;
    while k0 < kb {
        let kc = inst_k.min(kb - k0);
        let a_chunk = &a_cm[k0 * m..k0 * m + m * kc];
        let b_chunk = &b[k0 * n..(k0 + kc) * n];
        mma_tile_acc(d, a_chunk, b_chunk, m, n, kc, cfg);
        k0 += kc;
    }
}

/// Instruction-chunked RZ-avoidance walk over a chunk-major A panel:
/// per chunk, run the MMA with a **zero** C fragment into `tmp`, then add
/// into `acc` on the FP32 (RN) datapath — the paper's Fig. 6 (right)
/// pattern, with the external-add telemetry recorded per chunk exactly
/// like the reference backends. `tmp` is caller-owned scratch (m×n),
/// so the engine's arena replaces the reference's per-k-block `vec!`.
#[allow(clippy::too_many_arguments)]
pub fn mma_external_acc_chunked(
    acc: &mut [f32],
    tmp: &mut [f32],
    a_cm: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    kb: usize,
    inst_k: usize,
    cfg: MmaConfig,
) {
    debug_assert_eq!(acc.len(), m * n);
    debug_assert_eq!(tmp.len(), m * n);
    let mut k0 = 0;
    while k0 < kb {
        let kc = inst_k.min(kb - k0);
        let a_chunk = &a_cm[k0 * m..k0 * m + m * kc];
        let b_chunk = &b[k0 * n..(k0 + kc) * n];
        mma_tile_zero_into(tmp, a_chunk, b_chunk, m, n, kc, cfg);
        for (c, t) in acc.iter_mut().zip(tmp.iter()) {
            *c += *t; // FP32 RN add — the paper's Fig. 6 (right)
        }
        crate::telemetry::numeric::record(
            crate::telemetry::numeric::Counter::ExtRnAdds,
            (m * n) as u64,
        );
        k0 += kc;
    }
}

/// Convenience: `d += a×b` with a zero C tile (the paper's RZ-avoidance
/// pattern feeds a zero fragment and accumulates outside — see
/// [`mma_into_external_accumulator`] for that outside step).
pub fn mma_tile_zero_c(
    d: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    cfg: MmaConfig,
) {
    mma_tile_zero_into(d, a, b, m, n, k, cfg);
}

/// The paper's fix (Fig. 6 right): run the MMA with a **zero** C fragment,
/// then add the result into the FP32 running sum on the SIMT datapath,
/// which rounds with RN. `acc += mma(a, b, 0)`.
pub fn mma_into_external_accumulator(
    acc: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    cfg: MmaConfig,
) {
    let mut tmp = vec![0.0f32; m * n];
    mma_tile_zero_into(&mut tmp, a, b, m, n, k, cfg);
    for (dst, t) in acc.iter_mut().zip(tmp.iter()) {
        *dst += *t; // native f32 add = RN = the FP32 SIMT core
    }
    crate::telemetry::numeric::record(
        crate::telemetry::numeric::Counter::ExtRnAdds,
        (m * n) as u64,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{Half, Rounding};

    fn to_f16_grid(v: &[f32]) -> Vec<f32> {
        v.iter().map(|&x| Half::from_f32(x, Rounding::RN).to_f32()).collect()
    }

    #[test]
    fn exact_small_products() {
        // Integers are exact in f16 and their products exact in the
        // accumulator: result must be the true product in every config.
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let c = vec![1.0, 0.0, 0.0, -1.0];
        let expect = [1.0 * 5.0 + 2.0 * 7.0 + 1.0, 1.0 * 6.0 + 2.0 * 8.0,
                      3.0 * 5.0 + 4.0 * 7.0, 3.0 * 6.0 + 4.0 * 8.0 - 1.0];
        for cfg in [MmaConfig::TENSOR_CORE, MmaConfig::MMA_RN] {
            let mut d = vec![0.0f32; 4];
            mma_tile(&mut d, &a, &b, &c, 2, 2, 2, cfg);
            assert_eq!(d, expect);
        }
    }

    #[test]
    fn rz_biases_toward_zero_rn_does_not() {
        // Accumulate many values that each require rounding: RZ must
        // produce a systematically smaller (toward-zero) sum than RN,
        // and RN must be closer to the exact sum.
        let k = 256;
        let a: Vec<f32> = to_f16_grid(
            &(0..k).map(|i| 1.0 + (i as f32) * 1.9073486e-6).collect::<Vec<_>>(),
        );
        let b: Vec<f32> = to_f16_grid(
            &(0..k).map(|i| 1.0 / 3.0 + (i as f32) * 1e-4).collect::<Vec<_>>(),
        );
        let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let mut d_rz = vec![0.0f32];
        let mut d_rn = vec![0.0f32];
        mma_tile(&mut d_rz, &a, &b, &[0.0], 1, 1, k, MmaConfig::MMA_RZ);
        mma_tile(&mut d_rn, &a, &b, &[0.0], 1, 1, k, MmaConfig::MMA_RN);
        assert!(d_rz[0] as f64 <= exact);
        assert!(
            (d_rn[0] as f64 - exact).abs() <= (d_rz[0] as f64 - exact).abs(),
            "rn={} rz={} exact={exact}",
            d_rn[0],
            d_rz[0]
        );
    }

    #[test]
    fn external_accumulation_matches_simt_rn() {
        // With the zero-C trick, K-step blocked accumulation must equal a
        // plain f32 (RN) accumulation of the per-block exact products.
        let m = 4;
        let n = 4;
        let kb = 8;
        let blocks = 16;
        let mut state = 777u64;
        let mut rnd = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
        };
        let mut acc = vec![0.0f32; m * n];
        let mut ref_acc = vec![0.0f32; m * n];
        for _ in 0..blocks {
            let a: Vec<f32> = to_f16_grid(&(0..m * kb).map(|_| rnd()).collect::<Vec<_>>());
            let b: Vec<f32> = to_f16_grid(&(0..kb * n).map(|_| rnd()).collect::<Vec<_>>());
            mma_into_external_accumulator(&mut acc, &a, &b, m, n, kb, MmaConfig::TENSOR_CORE);
            // Reference: exact tile product rounded once to f32, added RN.
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f64;
                    for l in 0..kb {
                        s += a[i * kb + l] as f64 * b[l * n + j] as f64;
                    }
                    // The zero-C MMA's internal RZ on a short k=8 dot product
                    // of f16 inputs: products are <= 22 bits, partial sums of
                    // 8 of them fit in 25 bits => exact, so s rounds once.
                    ref_acc[i * n + j] += round_to_precision(s, 24, Rounding::RZ) as f32;
                }
            }
        }
        // The k=8 inner sums are *not* always exact in 25 bits (different
        // exponents), so allow ulp-level slack while requiring near-identity.
        for (x, y) in acc.iter().zip(ref_acc.iter()) {
            assert!((x - y).abs() <= 2.0 * x.abs() * f32::EPSILON + 1e-7, "{x} vs {y}");
        }
    }

    #[test]
    fn rz_fast_path_matches_generic() {
        // The masked-truncation specialization must agree bit-for-bit with
        // the generic rounding path on random f16-grid workloads.
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut rnd = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
        };
        for _ in 0..20 {
            let (m, n, k) = (5usize, 7usize, 13usize);
            let a = to_f16_grid(&(0..m * k).map(|_| rnd()).collect::<Vec<_>>());
            let b = to_f16_grid(&(0..k * n).map(|_| rnd()).collect::<Vec<_>>());
            let mut d_fast = (0..m * n).map(|_| rnd()).collect::<Vec<_>>();
            let mut d_gen = d_fast.clone();
            mma_tile_acc(&mut d_fast, &a, &b, m, n, k, MmaConfig::TENSOR_CORE);
            // Generic path: force it by using a config the specialization
            // rejects... instead call the scalar reference directly.
            for i in 0..m {
                for j in 0..n {
                    let mut acc = d_gen[i * n + j] as f64;
                    for l in 0..k {
                        acc = round_to_precision(
                            acc + a[i * k + l] as f64 * b[l * n + j] as f64,
                            25,
                            Rounding::RZ,
                        );
                    }
                    d_gen[i * n + j] = round_to_precision(acc, 24, Rounding::RZ) as f32;
                }
            }
            assert_eq!(d_fast, d_gen);
        }
    }

    /// Pack a row-major m×kb panel into the chunk-major layout
    /// `mma_tile_acc_chunked` consumes.
    fn pack_chunk_major(a: &[f32], m: usize, kb: usize, inst_k: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(m * kb);
        let mut k0 = 0;
        while k0 < kb {
            let kc = inst_k.min(kb - k0);
            for i in 0..m {
                out.extend_from_slice(&a[i * kb + k0..i * kb + k0 + kc]);
            }
            k0 += kc;
        }
        out
    }

    #[test]
    fn chunked_walkers_match_per_chunk_reference() {
        // The chunk-major walkers must agree bit-for-bit (and in FMA
        // counter totals) with the reference pattern: repack each chunk
        // from the row-major panel and call the mma per chunk.
        let inst_k = 8;
        let mut state = 0xfeed_beef_1234_5678u64;
        let mut rnd = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
        };
        for (m, n, kb) in [(5usize, 7usize, 13usize), (4, 4, 8), (3, 9, 20), (1, 1, 17)] {
            let a = to_f16_grid(&(0..m * kb).map(|_| rnd()).collect::<Vec<_>>());
            let b = to_f16_grid(&(0..kb * n).map(|_| rnd()).collect::<Vec<_>>());
            let a_cm = pack_chunk_major(&a, m, kb, inst_k);
            for cfg in [MmaConfig::TENSOR_CORE, MmaConfig::MMA_RN] {
                // Accumulate variant.
                let mut d_ref = (0..m * n).map(|_| rnd()).collect::<Vec<_>>();
                let mut d_eng = d_ref.clone();
                let mut k0 = 0;
                reset_fma_count();
                while k0 < kb {
                    let kc = inst_k.min(kb - k0);
                    let mut a_chunk = Vec::with_capacity(m * kc);
                    for i in 0..m {
                        a_chunk.extend_from_slice(&a[i * kb + k0..i * kb + k0 + kc]);
                    }
                    mma_tile_acc(&mut d_ref, &a_chunk, &b[k0 * n..(k0 + kc) * n], m, n, kc, cfg);
                    k0 += kc;
                }
                let fma_ref = fma_count();
                reset_fma_count();
                mma_tile_acc_chunked(&mut d_eng, &a_cm, &b, m, n, kb, inst_k, cfg);
                assert_eq!(fma_count(), fma_ref, "fma totals {m}x{n}x{kb}");
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&d_ref), bits(&d_eng), "acc {m}x{n}x{kb} cfg={cfg:?}");

                // External-accumulate (zero-C) variant.
                let mut acc_ref = (0..m * n).map(|_| rnd()).collect::<Vec<_>>();
                let mut acc_eng = acc_ref.clone();
                let mut tmp = vec![0.0f32; m * n];
                let mut k0 = 0;
                while k0 < kb {
                    let kc = inst_k.min(kb - k0);
                    let mut a_chunk = Vec::with_capacity(m * kc);
                    for i in 0..m {
                        a_chunk.extend_from_slice(&a[i * kb + k0..i * kb + k0 + kc]);
                    }
                    let bc = &b[k0 * n..(k0 + kc) * n];
                    mma_tile_zero_into(&mut tmp, &a_chunk, bc, m, n, kc, cfg);
                    for (c, t) in acc_ref.iter_mut().zip(tmp.iter()) {
                        *c += *t;
                    }
                    k0 += kc;
                }
                mma_external_acc_chunked(&mut acc_eng, &mut tmp, &a_cm, &b, m, n, kb, inst_k, cfg);
                assert_eq!(bits(&acc_ref), bits(&acc_eng), "ext {m}x{n}x{kb} cfg={cfg:?}");
            }
        }
    }

    #[test]
    fn fma_counter_counts() {
        reset_fma_count();
        let a = vec![1.0f32; 16 * 8];
        let b = vec![1.0f32; 8 * 8];
        let mut d = vec![0.0f32; 16 * 8];
        mma_tile_zero_c(&mut d, &a, &b, 16, 8, 8, MmaConfig::TENSOR_CORE);
        assert_eq!(fma_count(), 16 * 8 * 8);
    }
}
