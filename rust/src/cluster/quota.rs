//! Per-tenant token-bucket quotas, layered *above* the nodes' two-lane
//! intake (DESIGN.md §15).
//!
//! The nodes' `queue_cap` admission control protects each service from
//! aggregate overload; it cannot stop one tenant from starving the rest.
//! The cluster closes that gap with one token bucket per tag: a call
//! spends one token at submit, buckets refill continuously at
//! `refill_per_s` up to `burst`, and an empty bucket rejects the call with
//! `ServiceError::QueueFull` *before* any node sees it — quota exhaustion
//! is load-shedding, expressed in the existing error taxonomy. Untagged
//! traffic shares one anonymous bucket, so "no tag" is itself a tenant
//! rather than a bypass.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Per-tenant quota parameters (one bucket per distinct call tag).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuotaConfig {
    /// Bucket capacity: the largest burst a tenant may submit at once.
    pub burst: u64,
    /// Continuous refill rate in tokens per second (0 = no refill: `burst`
    /// calls total, useful for tests and hard caps).
    pub refill_per_s: f64,
}

impl Default for QuotaConfig {
    fn default() -> Self {
        QuotaConfig { burst: 64, refill_per_s: 64.0 }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// The cluster's quota ledger: lazily-created token buckets keyed by tag.
pub(crate) struct TenantQuotas {
    cfg: QuotaConfig,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl TenantQuotas {
    pub(crate) fn new(cfg: QuotaConfig) -> TenantQuotas {
        TenantQuotas { cfg, buckets: Mutex::new(HashMap::new()) }
    }

    /// The configured burst capacity (reported in `QueueFull::queue_cap`).
    pub(crate) fn burst(&self) -> u64 {
        self.cfg.burst
    }

    /// Try to spend one token from `tenant`'s bucket at time `now`.
    /// `None` tags draw from the shared anonymous bucket.
    pub(crate) fn try_acquire(&self, tenant: Option<&str>, now: Instant) -> bool {
        let key = tenant.unwrap_or("");
        let cap = self.cfg.burst as f64;
        // tclint: allow(hot-unwrap) -- poison propagation: a panicked ledger holder
        let mut buckets = self.buckets.lock().unwrap();
        let b = buckets
            .entry(key.to_string())
            .or_insert_with(|| Bucket { tokens: cap, last: now });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * self.cfg.refill_per_s).min(cap);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn burst_then_dry_without_refill() {
        let q = TenantQuotas::new(QuotaConfig { burst: 2, refill_per_s: 0.0 });
        let t0 = Instant::now();
        assert!(q.try_acquire(Some("a"), t0));
        assert!(q.try_acquire(Some("a"), t0));
        assert!(!q.try_acquire(Some("a"), t0), "burst spent, no refill");
        // Tenants are isolated: `b` has its own full bucket.
        assert!(q.try_acquire(Some("b"), t0));
        // Untagged traffic is its own tenant, not a bypass.
        assert!(q.try_acquire(None, t0));
        assert!(q.try_acquire(None, t0));
        assert!(!q.try_acquire(None, t0));
    }

    #[test]
    fn refill_restores_tokens() {
        let q = TenantQuotas::new(QuotaConfig { burst: 1, refill_per_s: 10.0 });
        let t0 = Instant::now();
        assert!(q.try_acquire(Some("t"), t0));
        assert!(!q.try_acquire(Some("t"), t0));
        // 200 ms at 10 tokens/s refills 2 tokens, capped at burst = 1.
        let later = t0 + Duration::from_millis(200);
        assert!(q.try_acquire(Some("t"), later));
        assert!(!q.try_acquire(Some("t"), later), "cap enforced");
    }
}
