//! The hi/lo split schemes at the heart of the paper.
//!
//! A single-precision value `v` is approximated by a low-precision pair
//! `(hi, lo)` so that Tensor-Core products of the pieces can reconstruct a
//! (nearly) FP32-accurate product:
//!
//! * **Markidis** (eqs. 2–5): `hi = toFP16(v)`, `lo = toFP16(v - hi)` — no
//!   scaling, so `lo` frequently lands in the FP16 subnormal range or
//!   underflows entirely (the paper's Fig. 8).
//! * **Ootomo (this paper)** (eqs. 19–22): `lo = toFP16((v - hi) · 2^11)` —
//!   the exponent shift of `l_F16 + 1 = 11` cancels the exponent drop of the
//!   residual, all but eliminating (gradual) underflow. The correction
//!   product is divided back by `2^11` (eq. 24).
//! * **Feng (EGEMM-TC)**: "round-split" — the rounding direction of `hi` is
//!   chosen by the 21st mantissa bit of `v` (as literally described in their
//!   paper, which Ootomo & Yokota argue is off by one due to the implicit
//!   bit); no residual scaling.
//! * **tf32tf32**: the Ootomo split with TF32 pieces (RNA conversion),
//!   retaining FP32's full exponent range.
//! * **bf16 triple** (TPU extension, see DESIGN §Hardware-Adaptation):
//!   three bfloat16 pieces at scales `1, 2^8, 2^16`.

use super::half::Half;
use super::rounding::{exp2i, Rounding};
use super::tf32::Tf32;
use crate::telemetry::numeric::{self, Counter};

/// Telemetry classification of a low piece (the paper's Fig. 8 hazard):
/// a *nonzero* residual whose low-precision conversion flushed to ±0 is
/// a total underflow; one that landed in the subnormal range kept some
/// mantissa but lost precision gradually. Pure observation — the split
/// itself is never altered, so enabling telemetry cannot perturb a bit.
#[inline]
fn count_f16_underflow(resid: f64, lo: Half) {
    if !numeric::enabled() || resid == 0.0 {
        return;
    }
    if lo.is_zero() {
        numeric::record(Counter::SplitFlushed, 1);
    } else if lo.is_subnormal() {
        numeric::record(Counter::SplitSubnormal, 1);
    }
}

/// [`count_f16_underflow`] for TF32 pieces (and bf16 pieces stored as
/// f32): both share f32's exponent range, so subnormal-ness is f32's.
#[inline]
fn count_f32_graded_underflow(resid: f64, lo: f32) {
    if !numeric::enabled() || resid == 0.0 {
        return;
    }
    if lo == 0.0 {
        numeric::record(Counter::SplitFlushed, 1);
    } else if lo.is_subnormal() {
        numeric::record(Counter::SplitSubnormal, 1);
    }
}

/// The residual scaling exponent: `l_F16 + 1 = 11`, i.e. ×2048 (eq. 18).
pub const SCALE_EXP: i32 = 11;
/// `2^11` as f32/f64-exact constant.
pub const SCALE: f32 = 2048.0;

/// The bf16 residual scaling exponent (`l_BF16 + 1 = 8`).
pub const BF16_SCALE_EXP: i32 = 8;

/// An FP16 hi/lo pair. `lo_scaled` records whether `lo` carries the ×2^11
/// factor (Ootomo) or not (Markidis/Feng).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SplitF16 {
    pub hi: Half,
    pub lo: Half,
    pub lo_scaled: bool,
}

impl SplitF16 {
    /// Exact reconstruction `hi + lo (/ 2^11 if scaled)` in f64.
    pub fn reconstruct(&self) -> f64 {
        let lo = self.lo.to_f64();
        let lo = if self.lo_scaled { lo * exp2i(-SCALE_EXP) } else { lo };
        self.hi.to_f64() + lo
    }
}

/// A TF32 hi/lo pair (always scaled — the paper's tf32tf32 method).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SplitTf32 {
    pub hi: Tf32,
    pub lo: Tf32,
}

impl SplitTf32 {
    pub fn reconstruct(&self) -> f64 {
        self.hi.to_f64() + self.lo.to_f64() * exp2i(-SCALE_EXP)
    }
}

/// Markidis et al. split (eqs. 2–5): RN conversions, unscaled residual.
pub fn split_markidis(v: f32) -> SplitF16 {
    let hi = Half::from_f32(v, Rounding::RN);
    let resid = v as f64 - hi.to_f64();
    let lo = Half::from_f64(resid, Rounding::RN);
    count_f16_underflow(resid, lo);
    SplitF16 { hi, lo, lo_scaled: false }
}

/// This paper's halfhalf split (eqs. 19–22): RN conversions, residual
/// scaled by 2^11 *before* the FP16 conversion (eq. 18).
pub fn split_ootomo(v: f32) -> SplitF16 {
    let hi = Half::from_f32(v, Rounding::RN);
    let resid = (v as f64 - hi.to_f64()) * exp2i(SCALE_EXP);
    let lo = Half::from_f64(resid, Rounding::RN);
    count_f16_underflow(resid, lo);
    SplitF16 { hi, lo, lo_scaled: true }
}

/// Feng et al.'s round-split, implemented as literally described: inspect
/// the 21st mantissa bit (from the MSB, 1-indexed over the 23 stored bits,
/// i.e. bit m2) of `v` and round `hi` away from zero if it is set, toward
/// zero otherwise. The residual is converted with RN and left unscaled.
pub fn split_feng(v: f32) -> SplitF16 {
    let m = v.to_bits() & 0x7f_ffff;
    let bit21 = (m >> 2) & 1; // m22 is the 1st bit, m2 the 21st
    let mode = if bit21 == 1 { Rounding::RA } else { Rounding::RZ };
    let hi = Half::from_f32(v, mode);
    let resid = v as f64 - hi.to_f64();
    let lo = Half::from_f64(resid, Rounding::RN);
    count_f16_underflow(resid, lo);
    SplitF16 { hi, lo, lo_scaled: false }
}

/// Markidis-style split but with RZ conversions (the "Truncate-Split"
/// baseline Feng et al. analyze; also used for Table 2's expectation).
pub fn split_markidis_rz(v: f32) -> SplitF16 {
    let hi = Half::from_f32(v, Rounding::RZ);
    let resid = v as f64 - hi.to_f64();
    let lo = Half::from_f64(resid, Rounding::RZ);
    count_f16_underflow(resid, lo);
    SplitF16 { hi, lo, lo_scaled: false }
}

/// This paper's tf32tf32 split: RNA conversions (keeps more mantissa than
/// RZ — §"Expectation of mantissa length"), residual scaled by 2^11.
pub fn split_ootomo_tf32(v: f32) -> SplitTf32 {
    let hi = Tf32::from_f32(v, Rounding::RNA);
    let resid = (v as f64 - hi.to_f64()) * exp2i(SCALE_EXP);
    let lo = Tf32::from_f64(resid, Rounding::RNA);
    count_f32_graded_underflow(resid, lo.to_f32());
    SplitTf32 { hi, lo }
}

/// bf16 triple split (TPU-idiomatic extension): `v ≈ b0 + b1/2^8 + b2/2^16`,
/// each piece a bfloat16 value (stored as the f32 it equals), residuals
/// scaled by 2^8 per level to dodge underflow exactly like eq. 18.
pub fn split_bf16_triple(v: f32) -> (f32, f32, f32) {
    use super::rounding::{round_to_format, Format};
    let s = exp2i(BF16_SCALE_EXP);
    let b0 = round_to_format(v as f64, Format::BF16, Rounding::RN);
    let r1 = (v as f64 - b0) * s;
    let b1 = round_to_format(r1, Format::BF16, Rounding::RN);
    let r2 = (r1 - b1) * s;
    let b2 = round_to_format(r2, Format::BF16, Rounding::RN);
    count_f32_graded_underflow(r1, b1 as f32);
    count_f32_graded_underflow(r2, b2 as f32);
    (b0 as f32, b1 as f32, b2 as f32)
}

/// Reconstruct a bf16 triple.
pub fn reconstruct_bf16_triple(t: (f32, f32, f32)) -> f64 {
    let s = exp2i(-BF16_SCALE_EXP);
    t.0 as f64 + (t.1 as f64) * s + (t.2 as f64) * s * s
}

// ---------------------------------------------------------------------------
// Whole-panel (SoA) splitters — the production engine's split stage
// ---------------------------------------------------------------------------
//
// Each panel function performs the *same per-element kernel* as its scalar
// counterpart above (same `Half`/`Tf32`/`round_to_format` calls, same
// operation order per element), restructured as one rounding pass per
// plane over a contiguous panel (structure-of-arrays: the hi plane and lo
// plane are separate contiguous buffers instead of per-element pairs).
// Because every split is a pure elementwise map, the pass structure cannot
// change a bit — pinned by `panel_splits_bit_identical_to_scalar` below
// and by the engine-vs-reference property suite. Underflow telemetry is
// tallied locally and recorded once per panel (identical totals to the
// per-element helpers; the enabled flag is read once per panel instead of
// once per element).

/// Local tally of the Fig. 8 underflow classification for one panel,
/// recorded in one [`numeric::record`] call per counter on `record()`.
struct UnderflowTally {
    on: bool,
    flushed: u64,
    subnormal: u64,
}

impl UnderflowTally {
    fn new() -> UnderflowTally {
        UnderflowTally { on: numeric::enabled(), flushed: 0, subnormal: 0 }
    }

    /// Classification of [`count_f16_underflow`], tallied instead of recorded.
    #[inline]
    fn f16(&mut self, resid: f64, lo: Half) {
        if !self.on || resid == 0.0 {
            return;
        }
        if lo.is_zero() {
            self.flushed += 1;
        } else if lo.is_subnormal() {
            self.subnormal += 1;
        }
    }

    /// Classification of [`count_f32_graded_underflow`], tallied.
    #[inline]
    fn f32_graded(&mut self, resid: f64, lo: f32) {
        if !self.on || resid == 0.0 {
            return;
        }
        if lo == 0.0 {
            self.flushed += 1;
        } else if lo.is_subnormal() {
            self.subnormal += 1;
        }
    }

    fn record(self) {
        // `record` is a no-op for n == 0, so a clean panel costs nothing.
        numeric::record(Counter::SplitFlushed, self.flushed);
        numeric::record(Counter::SplitSubnormal, self.subnormal);
    }
}

/// Refill `hi`/`lo` (and the f64 residual scratch) for a hi-pass over
/// `src` with per-element rounding mode chosen by `mode_of`, residuals
/// scaled by `2^scale_exp`. Shared by the three f16 panel splitters —
/// they differ only in the hi rounding mode and the residual scale.
#[inline]
fn f16_hi_pass(
    src: &[f32],
    scale_exp: i32,
    mode_of: impl Fn(f32) -> Rounding,
    hi: &mut Vec<f32>,
    resid: &mut Vec<f64>,
) {
    hi.clear();
    hi.reserve(src.len());
    resid.clear();
    resid.reserve(src.len());
    let scale = exp2i(scale_exp);
    for &v in src {
        let h = Half::from_f32(v, mode_of(v));
        resid.push((v as f64 - h.to_f64()) * scale);
        hi.push(h.to_f32());
    }
}

/// Batched lo-pass: one FP16 rounding sweep over the residual panel,
/// with the per-panel underflow tally.
#[inline]
fn f16_lo_pass(resid: &[f64], lo: &mut Vec<f32>) {
    lo.clear();
    lo.reserve(resid.len());
    let mut tally = UnderflowTally::new();
    for &r in resid {
        let l = Half::from_f64(r, Rounding::RN);
        tally.f16(r, l);
        lo.push(l.to_f32());
    }
    tally.record();
}

/// Whole-panel [`split_markidis`]: fills contiguous hi/lo planes.
pub fn split_panel_markidis(src: &[f32], hi: &mut Vec<f32>, lo: &mut Vec<f32>) {
    let mut resid = Vec::new();
    f16_hi_pass(src, 0, |_| Rounding::RN, hi, &mut resid);
    f16_lo_pass(&resid, lo);
}

/// Whole-panel [`split_ootomo`]: residuals scaled by 2^11 before the
/// batched FP16 rounding pass (eq. 18).
pub fn split_panel_ootomo(src: &[f32], hi: &mut Vec<f32>, lo: &mut Vec<f32>) {
    let mut resid = Vec::new();
    f16_hi_pass(src, SCALE_EXP, |_| Rounding::RN, hi, &mut resid);
    f16_lo_pass(&resid, lo);
}

/// Whole-panel [`split_feng`]: the hi rounding direction is chosen
/// per element by the 21st mantissa bit, exactly as in the scalar kernel.
pub fn split_panel_feng(src: &[f32], hi: &mut Vec<f32>, lo: &mut Vec<f32>) {
    let mut resid = Vec::new();
    let mode_of = |v: f32| {
        let m = v.to_bits() & 0x7f_ffff;
        if (m >> 2) & 1 == 1 { Rounding::RA } else { Rounding::RZ }
    };
    f16_hi_pass(src, 0, mode_of, hi, &mut resid);
    f16_lo_pass(&resid, lo);
}

/// Whole-panel [`split_ootomo_tf32`]: RNA conversions, 2^11 residual
/// scale, TF32 pieces stored as the f32 values they equal.
pub fn split_panel_ootomo_tf32(src: &[f32], hi: &mut Vec<f32>, lo: &mut Vec<f32>) {
    hi.clear();
    hi.reserve(src.len());
    lo.clear();
    lo.reserve(src.len());
    let mut resid = Vec::with_capacity(src.len());
    let scale = exp2i(SCALE_EXP);
    for &v in src {
        let h = Tf32::from_f32(v, Rounding::RNA);
        resid.push((v as f64 - h.to_f64()) * scale);
        hi.push(h.to_f32());
    }
    let mut tally = UnderflowTally::new();
    for &r in resid.iter() {
        let l = Tf32::from_f64(r, Rounding::RNA);
        tally.f32_graded(r, l.to_f32());
        lo.push(l.to_f32());
    }
    tally.record();
}

/// Whole-panel [`split_bf16_triple`]: three plane-at-a-time batched
/// rounding passes ([`round_panel_to_format`]) with the inter-plane
/// residual/scale arithmetic done on whole panels in between — the same
/// per-element f64 operation sequence as the scalar kernel.
pub fn split_panel_bf16_triple(
    src: &[f32],
    b0: &mut Vec<f32>,
    b1: &mut Vec<f32>,
    b2: &mut Vec<f32>,
) {
    use super::rounding::{round_panel_to_format, Format};
    let s = exp2i(BF16_SCALE_EXP);
    let n = src.len();
    // Widen once; `w` then carries the running residual panel.
    let mut w: Vec<f64> = Vec::with_capacity(n);
    for &v in src {
        w.push(v as f64);
    }
    let mut p0 = Vec::new();
    let mut p1 = Vec::new();
    let mut p2 = Vec::new();
    round_panel_to_format(&w, Format::BF16, Rounding::RN, &mut p0);
    for i in 0..n {
        w[i] = (w[i] - p0[i]) * s; // r1 panel
    }
    round_panel_to_format(&w, Format::BF16, Rounding::RN, &mut p1);
    let mut tally = UnderflowTally::new();
    b0.clear();
    b0.reserve(n);
    b1.clear();
    b1.reserve(n);
    b2.clear();
    b2.reserve(n);
    for i in 0..n {
        let v1 = p1[i] as f32;
        tally.f32_graded(w[i], v1);
        b0.push(p0[i] as f32);
        b1.push(v1);
        w[i] = (w[i] - p1[i]) * s; // r2 panel
    }
    round_panel_to_format(&w, Format::BF16, Rounding::RN, &mut p2);
    for i in 0..n {
        let v2 = p2[i] as f32;
        tally.f32_graded(w[i], v2);
        b2.push(v2);
    }
    tally.record();
}

/// Whole-panel FP16 quantization (RN) — the plain-Tensor-Core grid pass
/// (`Grid::F16` in `gemm::backends`).
pub fn quantize_panel_f16(src: &[f32], dst: &mut Vec<f32>) {
    dst.clear();
    dst.reserve(src.len());
    for &v in src {
        dst.push(Half::from_f32(v, Rounding::RN).to_f32());
    }
}

/// Whole-panel TF32 quantization (RNA) — the `Grid::Tf32` pass.
pub fn quantize_panel_tf32(src: &[f32], dst: &mut Vec<f32>) {
    dst.clear();
    dst.reserve(src.len());
    for &v in src {
        dst.push(Tf32::from_f32(v, Rounding::RNA).to_f32());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_f32s(n: usize, seed: u64) -> Vec<f32> {
        // Uniform(-1,1) plus exponent-spread extremes.
        let mut out = Vec::with_capacity(n);
        let mut s = seed | 1;
        for i in 0..n {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let u = (s >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
            let v = match i % 4 {
                0 => (2.0 * u - 1.0) as f32,
                1 => ((2.0 * u - 1.0) * 1e-6) as f32,
                2 => ((2.0 * u - 1.0) * 1e6) as f32,
                _ => ((2.0 * u - 1.0) * 2f64.powi((i % 61) as i32 - 30)) as f32,
            };
            out.push(v);
        }
        out
    }

    #[test]
    fn markidis_residual_smaller_than_hi_ulp() {
        for v in sample_f32s(5000, 0xdead) {
            let s = split_markidis(v);
            // |v - hi| <= ulp(hi)/2 for RN (absolute ulp floor of 2^-24 in
            // the subnormal range).
            if v != 0.0 && !s.hi.is_zero() && !s.hi.is_infinite() {
                let ulp = (s.hi.to_f64().abs() * exp2i(-10)).max(exp2i(-24));
                assert!(
                    (v as f64 - s.hi.to_f64()).abs() <= 0.5 * ulp + 1e-300,
                    "v={v:e}"
                );
            }
        }
    }

    #[test]
    fn ootomo_reconstruction_at_least_as_good_as_markidis() {
        // Property: with the 2^11 scaling the residual cannot be *less*
        // accurate than without (underflow only hurts Markidis).
        for v in sample_f32s(20_000, 0xbeef) {
            if !v.is_finite() || v.abs() >= 65504.0 {
                continue;
            }
            let em = (split_markidis(v).reconstruct() - v as f64).abs();
            let eo = (split_ootomo(v).reconstruct() - v as f64).abs();
            assert!(eo <= em + 1e-300, "v={v:e} markidis_err={em:e} ootomo_err={eo:e}");
        }
    }

    #[test]
    fn ootomo_exact_in_comfortable_range() {
        // For exponents where 24 bits fit in hi+lo (most of urand(-1,1)),
        // the scaled split reconstructs v exactly at least 1/4 of the time
        // (Table 1: P(len=23) = 3/4 and len=23 means exact).
        let vals = sample_f32s(4000, 7)
            .into_iter()
            .filter(|v| v.abs() > 1e-3 && v.abs() < 1e3)
            .collect::<Vec<_>>();
        let exact = vals
            .iter()
            .filter(|&&v| split_ootomo(v).reconstruct() == v as f64)
            .count();
        assert!(
            exact as f64 / vals.len() as f64 > 0.5,
            "only {exact}/{} exact",
            vals.len()
        );
    }

    #[test]
    fn tf32_split_exact_over_wide_exponents() {
        // tf32tf32 keeps FP32's exponent range: the split must stay accurate
        // even at exponents where halfhalf is dead (Fig 9 / Fig 11 Type 4).
        for e in [-120i32, -80, -40, 0, 40, 80, 120] {
            let v = (1.7182818 * exp2i(e)) as f32;
            let s = split_ootomo_tf32(v);
            let err = (s.reconstruct() - v as f64).abs();
            let rel = err / (v as f64).abs();
            assert!(rel < exp2i(-21), "e={e} rel={rel:e}");
            // While halfhalf at e=-40 keeps nothing:
            if e <= -40 {
                let h = split_ootomo(v);
                assert!(h.hi.is_zero(), "halfhalf hi should underflow at e={e}");
            }
        }
    }

    #[test]
    fn feng_split_is_well_formed() {
        for v in sample_f32s(5000, 99) {
            if !v.is_finite() || v.abs() >= 32768.0 {
                continue;
            }
            let s = split_feng(v);
            // hi within 1 ulp of v (directed rounding), residual representable.
            if !s.hi.is_zero() && !s.hi.is_infinite() {
                let ulp = (s.hi.to_f64().abs() * exp2i(-10)).max(exp2i(-24));
                assert!((v as f64 - s.hi.to_f64()).abs() <= ulp + 1e-300, "v={v:e}");
            }
        }
    }

    #[test]
    fn scaling_does_not_change_mantissa() {
        // Eq. 18's claim: multiplying by 2^11 shifts the exponent only.
        // Where neither path over/underflows, lo(ootomo) == lo(markidis)*2^11.
        for v in sample_f32s(5000, 0x5eed) {
            if v.abs() < 1e-2 || v.abs() > 1e2 {
                continue;
            }
            let m = split_markidis(v);
            let o = split_ootomo(v);
            if !m.lo.is_zero() && !m.lo.is_subnormal() {
                assert_eq!(
                    o.lo.to_f64(),
                    m.lo.to_f64() * exp2i(SCALE_EXP),
                    "v={v:e}"
                );
            }
        }
    }

    /// Adversarial inputs for the panel-vs-scalar pinning test: ±0,
    /// subnormal-heavy values (the Fig. 8 hazard), f16-overflow range,
    /// non-finite operands, and an exponent sweep.
    fn adversarial_f32s() -> Vec<f32> {
        let mut vals = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            65504.0,
            65520.0,
            -1e30,
            f32::MAX,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::from_bits(1),           // min f32 subnormal
            f32::from_bits(0x8000_0001), // -min subnormal
            exp2i(-24) as f32,           // min f16 subnormal
            exp2i(-25) as f32,           // half of it
            (1.5 * exp2i(-24)) as f32,
        ];
        for v in sample_f32s(2000, 0xfeed) {
            vals.push(v);
        }
        // Subnormal-residual generators: hi lands normal, residual deep
        // below the f16 normal range.
        for e in -30..-10 {
            vals.push(((1.0 + exp2i(-12)) * exp2i(e)) as f32);
        }
        vals
    }

    #[test]
    fn panel_splits_bit_identical_to_scalar() {
        let src = adversarial_f32s();
        let (mut hi, mut lo) = (Vec::new(), Vec::new());

        split_panel_markidis(&src, &mut hi, &mut lo);
        for (i, &v) in src.iter().enumerate() {
            let s = split_markidis(v);
            assert_eq!(hi[i].to_bits(), s.hi.to_f32().to_bits(), "markidis hi v={v:e}");
            assert_eq!(lo[i].to_bits(), s.lo.to_f32().to_bits(), "markidis lo v={v:e}");
        }

        split_panel_ootomo(&src, &mut hi, &mut lo);
        for (i, &v) in src.iter().enumerate() {
            let s = split_ootomo(v);
            assert_eq!(hi[i].to_bits(), s.hi.to_f32().to_bits(), "ootomo hi v={v:e}");
            assert_eq!(lo[i].to_bits(), s.lo.to_f32().to_bits(), "ootomo lo v={v:e}");
        }

        split_panel_feng(&src, &mut hi, &mut lo);
        for (i, &v) in src.iter().enumerate() {
            let s = split_feng(v);
            assert_eq!(hi[i].to_bits(), s.hi.to_f32().to_bits(), "feng hi v={v:e}");
            assert_eq!(lo[i].to_bits(), s.lo.to_f32().to_bits(), "feng lo v={v:e}");
        }

        split_panel_ootomo_tf32(&src, &mut hi, &mut lo);
        for (i, &v) in src.iter().enumerate() {
            let s = split_ootomo_tf32(v);
            assert_eq!(hi[i].to_bits(), s.hi.to_f32().to_bits(), "tf32 hi v={v:e}");
            assert_eq!(lo[i].to_bits(), s.lo.to_f32().to_bits(), "tf32 lo v={v:e}");
        }

        let (mut b0, mut b1, mut b2) = (Vec::new(), Vec::new(), Vec::new());
        split_panel_bf16_triple(&src, &mut b0, &mut b1, &mut b2);
        for (i, &v) in src.iter().enumerate() {
            let (s0, s1, s2) = split_bf16_triple(v);
            assert_eq!(b0[i].to_bits(), s0.to_bits(), "bf16 b0 v={v:e}");
            assert_eq!(b1[i].to_bits(), s1.to_bits(), "bf16 b1 v={v:e}");
            assert_eq!(b2[i].to_bits(), s2.to_bits(), "bf16 b2 v={v:e}");
        }
    }

    #[test]
    fn panel_quantize_bit_identical_to_scalar() {
        use super::super::rounding::Rounding;
        let src = adversarial_f32s();
        let mut dst = Vec::new();
        quantize_panel_f16(&src, &mut dst);
        for (i, &v) in src.iter().enumerate() {
            let q = Half::from_f32(v, Rounding::RN).to_f32();
            assert_eq!(dst[i].to_bits(), q.to_bits(), "f16 v={v:e}");
        }
        quantize_panel_tf32(&src, &mut dst);
        for (i, &v) in src.iter().enumerate() {
            let q = Tf32::from_f32(v, Rounding::RNA).to_f32();
            assert_eq!(dst[i].to_bits(), q.to_bits(), "tf32 v={v:e}");
        }
    }

    #[test]
    fn panel_splits_reuse_capacity_and_clear() {
        // Stale contents of the destination planes must never leak.
        let (mut hi, mut lo) = (vec![9.0f32; 100], vec![9.0f32; 100]);
        split_panel_ootomo(&[1.0, 2.0], &mut hi, &mut lo);
        assert_eq!(hi.len(), 2);
        assert_eq!(lo.len(), 2);
        assert_eq!(hi[0], 1.0);
        split_panel_ootomo(&[], &mut hi, &mut lo);
        assert!(hi.is_empty() && lo.is_empty());
    }

    #[test]
    fn bf16_triple_recovers_f32() {
        // 3×8 = 24 significand bits: reconstruction must be f32-exact for
        // comfortably-ranged values.
        for v in sample_f32s(5000, 0xabcd) {
            if v.abs() < 1e-20 || v.abs() > 1e20 || !v.is_finite() {
                continue;
            }
            let t = split_bf16_triple(v);
            let r = reconstruct_bf16_triple(t);
            let rel = ((r - v as f64) / v as f64).abs();
            assert!(rel < exp2i(-23), "v={v:e} rel={rel:e}");
        }
    }
}
