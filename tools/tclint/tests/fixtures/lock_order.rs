// tclint-fixture-path: rust/src/runtime/fx_locks.rs
use std::sync::Mutex;

struct S {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl S {
    fn forward(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        drop(h);
        drop(g);
    }

    fn backward(&self) {
        let g = self.b.lock().unwrap();
        let h = self.a.lock().unwrap();
        drop(h);
        drop(g);
    }
}
