//! Figure 1 — accuracy of A(16×k)·B(k×16), urand(-1,1), vs k.
//!
//! Paper shape to reproduce: cublas_fp16tc worst and degrading with k;
//! markidis better but converging back to the TC line at large k (RZ
//! accumulation); feng ≈ markidis; cutlass_halfhalf == cublas_simt at
//! every k.
//!
//! Run: `cargo bench --bench fig1_accuracy`

use tcec::experiments;

fn main() {
    println!("== Figure 1: relative residual (eq. 7) vs k, urand(-1,1), 16xk * kx16 ==");
    println!("(bit-exact simulation; 8 seeds averaged — paper protocol)\n");
    let (ks, seeds): (Vec<usize>, u64) = if tcec::bench_util::smoke() {
        (vec![16, 64], 1)
    } else {
        ((4..=13).map(|p| 1usize << p).collect(), 8)
    };
    let t = experiments::fig1(&ks, seeds);
    t.print();
    println!("\nExpected shape: halfhalf tracks cublas_simt; markidis/feng sit between");
    println!("simt and fp16tc and converge toward fp16tc as k grows.");
}
